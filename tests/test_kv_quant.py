"""int8 KV cache (§Perf B3): accuracy + structural properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.partitioning import split


@pytest.fixture(scope="module")
def pair():
    cfg_q = dataclasses.replace(get_arch("yi-9b").reduced(), kv_quant=True)
    cfg_f = get_arch("yi-9b").reduced()
    m_q, m_f = registry.build(cfg_q), registry.build(cfg_f)
    params, _ = split(m_q.init(jax.random.PRNGKey(0)))
    batch = registry.make_batch(
        cfg_q, ShapeConfig("s", 24, 2, "train"), jax.random.PRNGKey(1))
    return cfg_q, m_q, m_f, params, batch["tokens"]


def test_cache_dtype_and_bytes(pair):
    cfg_q, m_q, _, _, _ = pair
    cache, _ = split(m_q.init_cache(2, 32))
    slot = cache["slots"][0]
    assert slot["k"].dtype == jnp.int8
    assert "k_scale" in slot and slot["k_scale"].dtype == jnp.float32
    from repro import analysis
    full = analysis.cache_bytes(dataclasses.replace(cfg_q, kv_quant=False,
                                                    dtype="bfloat16"),
                                2, 4096)
    quant = analysis.cache_bytes(dataclasses.replace(cfg_q,
                                                     dtype="bfloat16"),
                                 2, 4096)
    assert quant < 0.6 * full


@pytest.mark.slow
def test_decode_close_and_argmax_identical(pair):
    cfg_q, m_q, m_f, params, toks = pair
    cq, _ = split(m_q.init_cache(2, 32))
    cf, _ = split(m_f.init_cache(2, 32))
    _, cq = m_q.prefill(params, cq, {"tokens": toks[:, :16]})
    _, cf = m_f.prefill(params, cf, {"tokens": toks[:, :16]})
    for t in range(16, 20):
        dq, cq = m_q.decode_step(params, cq, {"tokens": toks[:, t]})
        df, cf = m_f.decode_step(params, cf, {"tokens": toks[:, t]})
        rel = float(jnp.max(jnp.abs(dq - df))
                    / (jnp.max(jnp.abs(df)) + 1e-9))
        assert rel < 0.08, rel
        # greedy decode must agree except on near-ties: with an untrained
        # model the logits are near-uniform, so int8 noise may flip an
        # argmax ONLY where the full-precision top-2 gap is within the
        # quantization error band
        aq, af = np.argmax(dq, -1), np.argmax(df, -1)
        for bi in np.flatnonzero(aq != af):
            gap = float(df[bi, af[bi]] - df[bi, aq[bi]])
            scale = float(np.max(np.abs(np.asarray(df[bi]))))
            assert gap <= 0.03 * scale, (
                f"argmax flip on a non-tie: gap={gap}, scale={scale}")


def test_quantize_roundtrip_error_bound():
    from repro.models.attention import _dequant, _quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64)) * 3.0
    q, s = _quantize(x)
    back = _dequant(q, s, jnp.float32)
    # symmetric int8: error <= scale/2 = amax/254 per element
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert bool(jnp.all(jnp.abs(back - x) <= amax / 254 + 1e-6))
