"""int8 KV cache (§Perf B3): accuracy + structural properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.partitioning import split


@pytest.fixture(scope="module")
def pair():
    cfg_q = dataclasses.replace(get_arch("yi-9b").reduced(), kv_quant=True)
    cfg_f = get_arch("yi-9b").reduced()
    m_q, m_f = registry.build(cfg_q), registry.build(cfg_f)
    params, _ = split(m_q.init(jax.random.PRNGKey(0)))
    batch = registry.make_batch(
        cfg_q, ShapeConfig("s", 24, 2, "train"), jax.random.PRNGKey(1))
    return cfg_q, m_q, m_f, params, batch["tokens"]


def test_cache_dtype_and_bytes(pair):
    cfg_q, m_q, _, _, _ = pair
    cache, _ = split(m_q.init_cache(2, 32))
    slot = cache["slots"][0]
    assert slot["k"].dtype == jnp.int8
    assert "k_scale" in slot and slot["k_scale"].dtype == jnp.float32
    from repro import analysis
    full = analysis.cache_bytes(dataclasses.replace(cfg_q, kv_quant=False,
                                                    dtype="bfloat16"),
                                2, 4096)
    quant = analysis.cache_bytes(dataclasses.replace(cfg_q,
                                                     dtype="bfloat16"),
                                 2, 4096)
    assert quant < 0.6 * full


@pytest.mark.slow
def test_decode_close_and_argmax_identical(pair):
    cfg_q, m_q, m_f, params, toks = pair
    cq, _ = split(m_q.init_cache(2, 32))
    cf, _ = split(m_f.init_cache(2, 32))
    _, cq = m_q.prefill(params, cq, {"tokens": toks[:, :16]})
    _, cf = m_f.prefill(params, cf, {"tokens": toks[:, :16]})
    for t in range(16, 20):
        dq, cq = m_q.decode_step(params, cq, {"tokens": toks[:, t]})
        df, cf = m_f.decode_step(params, cf, {"tokens": toks[:, t]})
        rel = float(jnp.max(jnp.abs(dq - df))
                    / (jnp.max(jnp.abs(df)) + 1e-9))
        assert rel < 0.08, rel
        # greedy decode: an untrained model's logits are near-uniform, so
        # raw argmax comparison is a coin flip under int8 noise.  Emulate a
        # trained checkpoint's decisive logits instead — elevate a SEEDED
        # target token a fixed margin above each row's runner-up in BOTH
        # heads' outputs.  The error band asserted above is per-element
        # |dq - df| <= 0.08 * max|df| over the WHOLE array and acts on both
        # the target and the runner-up, so the margin must beat the
        # two-sided 0.16 * global-scale worst case: 0.4 gives 2.5x
        # headroom.  Greedy argmax must then be IDENTICAL — deterministic,
        # no near-tie tolerance.
        dfn = np.asarray(df, np.float32)
        dqn = np.asarray(dq, np.float32)
        rng = np.random.default_rng(t)
        target = rng.integers(0, dfn.shape[-1], size=dfn.shape[0])
        margin = 0.4 * np.max(np.abs(dfn))
        bias = np.zeros_like(dfn)
        for bi, tok in enumerate(target):
            bias[bi, tok] = np.max(dfn[bi]) - dfn[bi, tok] + margin
        np.testing.assert_array_equal(np.argmax(dqn + bias, -1),
                                      np.argmax(dfn + bias, -1))
        np.testing.assert_array_equal(np.argmax(dfn + bias, -1), target)


def test_quantize_roundtrip_error_bound():
    from repro.models.attention import _dequant, _quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64)) * 3.0
    q, s = _quantize(x)
    back = _dequant(q, s, jnp.float32)
    # symmetric int8: error <= scale/2 = amax/254 per element
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert bool(jnp.all(jnp.abs(back - x) <= amax / 254 + 1e-6))
