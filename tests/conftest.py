import jax
import pytest

jax.config.update("jax_enable_x64", False)
# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real device; only launch/dryrun.py forces
# the 512-device placeholder topology.


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
