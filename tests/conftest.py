import jax
import pytest

jax.config.update("jax_enable_x64", False)
# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real device; only launch/dryrun.py forces
# the 512-device placeholder topology.


def pytest_configure(config):
    # quick loop: pytest -q -m "not slow"  (~quarter of the full runtime).
    # The tier-1 gate stays the FULL suite: PYTHONPATH=src pytest -x -q
    config.addinivalue_line(
        "markers", "slow: multi-second integration sweep; deselect with "
        "-m \"not slow\" for the quick loop")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
