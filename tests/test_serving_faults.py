"""The chaos path: seeded fault injection through the SlotEngine.

The headline invariant (ISSUE 9 / ROADMAP §Robustness): lanes never
interact, so under ANY seeded FaultPlan the healthy lanes' greedy tokens
are bit-identical to a fault-free run, every request terminates with a
finish_reason from the closed set, and the zero-allocation invariant
(``StatePool.stats.buffers_built`` stays at capacity) holds through
quarantine, retry and re-admission.

The property test proper needs hypothesis (a dev dependency — CI installs
it); a deterministic two-seed parametrisation of the same property runs
everywhere so the chaos path is never silently unexercised.
"""
import dataclasses
import gc

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import registry
from repro.partitioning import split
from repro.serving import (FINISH_REASONS, EngineConfig, FaultInjector,
                           FaultPlan, FinishReason, LanePoison,
                           PrefillFault, QueueFlood, Request, Result,
                           SlotEngine, SlowTick)
from repro import steps as steps_lib

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # hypothesis is a dev-only dependency
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def _release_compiled_state():
    # Engines are built per-test, so their jit closures (and the XLA
    # executables behind them) are garbage after each test.  Dropping them
    # eagerly keeps the long-lived suite process from accumulating native
    # compiler state across the many engine constructions in this module.
    yield
    gc.collect()
    jax.clear_caches()


def _tiny_cfg():
    return dataclasses.replace(
        get_arch("qwen2-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=128, vocab=128)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


LENS, NEWS = [5, 9, 3, 7], [6, 4, 8, 5]


def _requests(cfg, lens=LENS, news=NEWS, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab, (int(l),)).astype(np.int32),
                    max_new_tokens=int(m))
            for i, (l, m) in enumerate(zip(lens, news))]


@pytest.fixture(scope="module")
def baseline(tiny):
    """Fault-free reference tokens for the standard request set — what
    every healthy (finish_reason='length') lane must match bit-for-bit."""
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=8)
    results = engine.serve(_requests(cfg))
    assert all(r.finish_reason == FinishReason.LENGTH for r in results)
    return {r.uid: r.tokens for r in results}


class FakeClock:
    """Deterministic monotonic clock: advances 1.0 per call."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# FaultPlan / closed finish_reason set (no model)
# ---------------------------------------------------------------------------
def test_fault_plan_seeded_deterministic_and_json_roundtrip():
    kw = dict(n_slots=2, ticks=8, uids=(0, 1, 2), n_poison=2, n_prefill=1,
              n_slow_burst=1, n_flood=1)
    a, b = FaultPlan.seeded(7, **kw), FaultPlan.seeded(7, **kw)
    assert a == b                                  # structural determinism
    assert a != FaultPlan.seeded(8, **kw)
    assert FaultPlan.from_json(a.to_json()) == a
    kinds = {type(f) for f in a.faults}
    assert kinds == {LanePoison, PrefillFault, SlowTick, QueueFlood}


def test_result_rejects_reasons_outside_closed_set():
    empty = np.zeros((0,), np.int32)
    for reason in sorted(FINISH_REASONS):
        Result(0, empty, 0.0, 0.0, [], finish_reason=reason)
    with pytest.raises(ValueError, match="closed"):
        Result(0, empty, 0.0, 0.0, [], finish_reason="oom")


# ---------------------------------------------------------------------------
# Guard semantics at the steps level
# ---------------------------------------------------------------------------
def test_guarded_step_all_false_poison_is_bit_identical(tiny):
    cfg, model, params = tiny
    cache, _ = split(model.init_cache(2, 16))
    cache = dict(cache, pos=np.array([3, 0], np.int32))
    batch = {"tokens": np.array([7, 0], np.int32),
             "active": np.array([True, False])}
    ref_logits, ref_cache = steps_lib.masked_decode_step(
        cfg, params, jax.tree.map(np.copy, cache), dict(batch))
    logits, lane_ok, _ = steps_lib.guarded_decode_step(
        cfg, params, cache, dict(batch, poison=np.array([False, False])))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref_logits))
    # inactive lanes never report faults, whatever their logits hold
    assert np.asarray(lane_ok).tolist() == [True, True]
    poisoned, lane_ok, _ = steps_lib.guarded_decode_step(
        cfg, params, ref_cache, dict(batch, poison=np.array([True, False])))
    assert np.asarray(lane_ok).tolist() == [False, True]
    assert np.isnan(np.asarray(poisoned)[0]).all()


# ---------------------------------------------------------------------------
# Engine: DOA fast-fail, quarantine, retries, prefill faults
# ---------------------------------------------------------------------------
def test_submit_dead_on_arrival_publishes_deadline_result(tiny):
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=4, clock=FakeClock())
    req = Request(9, np.array([1, 2], np.int32), max_new_tokens=2,
                  deadline_s=0.5)
    assert engine.submit(req) is False
    assert len(engine.queue) == 0
    res = engine.take_finished()[9]
    assert res.finish_reason == FinishReason.DEADLINE
    assert res.tokens.shape[-1] == 0
    assert engine.metrics.counter("serving/deadline_miss").value == 1


def test_quarantine_without_budget_errors_healthy_lane_identical(
        tiny, baseline):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=(LanePoison(tick=1, lane=0),))
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=8, faults=faults)
    reqs = _requests(cfg, lens=LENS[:2], news=[6, 4])
    results = engine.serve(reqs)
    # uid0 (lane 0) quarantined at tick 1: admit token + tick-0 token kept,
    # the poisoned tick-1 token never recorded
    assert results[0].finish_reason == FinishReason.ERROR
    assert results[0].tokens.shape[-1] == 2
    np.testing.assert_array_equal(results[0].tokens, baseline[0][:2])
    # the neighbour lane never noticed
    assert results[1].finish_reason == FinishReason.LENGTH
    np.testing.assert_array_equal(results[1].tokens, baseline[1])
    assert engine.metrics.counter("serving/quarantined").value == 1
    assert engine.metrics.counter("serving/retries").value == 0
    assert engine.pool.stats.buffers_built == 1       # zero-alloc held


def test_quarantine_retry_regenerates_identical_tokens(tiny, baseline):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=(LanePoison(tick=1, lane=0),))
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=8, faults=faults, retry_budget=2)
    results = engine.serve(_requests(cfg, lens=LENS[:2], news=[6, 4]))
    # the retried request restarts from prefill, so greedy decode
    # regenerates exactly the fault-free tokens
    for r in results:
        assert r.finish_reason == FinishReason.LENGTH
        np.testing.assert_array_equal(r.tokens, baseline[r.uid])
    assert engine.metrics.counter("serving/quarantined").value == 1
    assert engine.metrics.counter("serving/retries").value == 1
    assert engine.pool.stats.buffers_built == 1


def test_retries_exhausted_under_persistent_poison(tiny):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=tuple(
        LanePoison(tick=t, lane=0) for t in range(64)))
    engine = SlotEngine(model, params, n_slots=1, max_seq=64,
                        queue_capacity=4, faults=faults, retry_budget=1)
    [res] = engine.serve(_requests(cfg, lens=[5], news=[4]))
    assert res.finish_reason == FinishReason.RETRIES_EXHAUSTED
    assert engine.metrics.counter("serving/retries").value == 1
    assert engine.metrics.counter("serving/quarantined").value == 2
    assert engine.pool.stats.buffers_built == 1


def test_prefill_fault_without_budget_is_error(tiny, baseline):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=(PrefillFault(uid=0),))
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=8, faults=faults)
    results = engine.serve(_requests(cfg, lens=LENS[:2], news=[6, 4]))
    assert results[0].finish_reason == FinishReason.ERROR
    assert results[0].tokens.shape[-1] == 0
    assert results[1].finish_reason == FinishReason.LENGTH
    np.testing.assert_array_equal(results[1].tokens, baseline[1])
    # injected prefill faults raise BEFORE the dispatch: the donated B=1
    # scratch survives and is never rebuilt
    assert engine._scratch_pool.stats.buffers_built == 1


def test_prefill_fault_with_budget_retries_to_length(tiny, baseline):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=(PrefillFault(uid=0),))
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=8, faults=faults, retry_budget=1)
    results = engine.serve(_requests(cfg, lens=LENS[:2], news=[6, 4]))
    for r in results:
        assert r.finish_reason == FinishReason.LENGTH
        np.testing.assert_array_equal(r.tokens, baseline[r.uid])
    assert engine.metrics.counter("serving/retries").value == 1
    assert engine._scratch_pool.stats.buffers_built == 1


# ---------------------------------------------------------------------------
# Chunked prefill x faults: per-ATTEMPT consumption, chunk-k targeting
# ---------------------------------------------------------------------------
def test_take_prefill_fault_is_per_attempt_and_chunk_targeted():
    """No model needed: the injector's chunk-matching semantics alone.
    A ``chunk=k`` fault skips attempts for earlier chunks, fires exactly
    once at chunk k, and is consumed — the retry's chunk-k attempt passes."""
    plan = FaultPlan(seed=0, faults=(PrefillFault(uid=1, chunk=2),))
    inj = FaultInjector(plan, 2, vocab=16, max_seq=32)
    assert not inj.take_prefill_fault(1, chunk=0)
    assert not inj.take_prefill_fault(1, chunk=1)
    assert inj.take_prefill_fault(1, chunk=2)
    assert not inj.take_prefill_fault(1, chunk=2)     # consumed per attempt
    # chunk=None (the whole-prompt path's meaning) matches ANY attempt
    inj2 = FaultInjector(FaultPlan(seed=0, faults=(PrefillFault(uid=3),)),
                         2, vocab=16, max_seq=32)
    assert inj2.take_prefill_fault(3, chunk=5)
    assert not inj2.take_prefill_fault(3, chunk=5)
    # the chunk field round-trips; pre-chunk plans (no field) still load
    p = FaultPlan(seed=1, faults=(PrefillFault(uid=2, chunk=1),))
    assert FaultPlan.from_json(p.to_json()) == p
    legacy = {"seed": 0, "faults": [{"kind": "PrefillFault", "uid": 4}]}
    assert FaultPlan.from_json(legacy).faults[0].chunk is None


def test_chunk_k_fault_discards_partial_state_retry_token_identical(tiny):
    """ISSUE 10 satellite: a fault at chunk k of a chunked admission
    discards the k chunks of partial scratch state; the retry restarts
    from chunk 0 and the final tokens are bit-identical to an unfaulted
    chunked run (which is itself identical to whole-prompt prefill)."""
    cfg, model, params = tiny
    def reqs():
        return _requests(cfg, lens=[13, 5], news=[4, 3])
    clean = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=64, queue_capacity=8,
        prefill_chunk_len=4, prefill_lanes=2))
    want = {r.uid: r.tokens for r in clean.serve(reqs())}

    # prompt_len=13, chunk_len=4 -> schedule [4,4,4,1]; fault the third
    # attempt (chunk=2), i.e. after 8 tokens of partial prefill state
    faults = FaultPlan(seed=0, faults=(PrefillFault(uid=0, chunk=2),))
    engine = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=64, queue_capacity=8,
        prefill_chunk_len=4, prefill_lanes=2,
        faults=faults, retry_budget=1))
    results = engine.serve(reqs())
    for r in results:
        assert r.finish_reason == FinishReason.LENGTH
        np.testing.assert_array_equal(r.tokens, want[r.uid])
    assert engine.metrics.counter("serving/retries").value == 1
    # injected faults raise BEFORE dispatch: the lane scratch survives,
    # is zero-reset on give_back, and the pool never rebuilds
    sp = engine._scratch_pool.stats
    assert sp.buffers_built == sp.capacity == 2
    assert sp.outstanding == 0


def test_chunk_k_fault_without_budget_is_error(tiny):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=(PrefillFault(uid=0, chunk=1),))
    engine = SlotEngine(model, params, config=EngineConfig(
        n_slots=1, max_seq=64, queue_capacity=4,
        prefill_chunk_len=4, prefill_lanes=1, faults=faults))
    [res] = engine.serve(_requests(cfg, lens=[9], news=[3]))
    assert res.finish_reason == FinishReason.ERROR
    assert res.tokens.shape[-1] == 0
    sp = engine._scratch_pool.stats
    assert sp.buffers_built == sp.capacity == 1
    assert sp.outstanding == 0


# ---------------------------------------------------------------------------
# Degradation ladder: watchdog downshift, shed, recovery
# ---------------------------------------------------------------------------
def test_ladder_degrades_sheds_and_recovers(tiny):
    cfg, model, params = tiny
    faults = FaultPlan(seed=0, faults=tuple(
        SlowTick(tick=t, extra_s=1e6) for t in range(3)))
    engine = SlotEngine(
        model, params, n_slots=2, max_seq=64, queue_capacity=4,
        extra_plans={"decode/fallback":
                     lambda p, c, b: steps_lib.decode_step(cfg, p, c, b)},
        faults=faults, tick_slo_s=50.0, slo_breach_ticks=3,
        slo_recover_ticks=3, ladder=["decode/base"])
    reqs = _requests(cfg, lens=[5, 9], news=[12, 12])
    # queued behind both lanes with a deadline far under the post-breach
    # tick EMA (~1e6 s): provably unmeetable once degraded -> shed
    doomed = Request(7, np.array([1, 2, 3], np.int32), max_new_tokens=4,
                     deadline_s=engine.clock() + 1000.0)
    results = engine.serve(reqs + [doomed])
    assert [r.finish_reason for r in results[:2]] == [
        FinishReason.LENGTH, FinishReason.LENGTH]
    assert results[2].finish_reason == FinishReason.SHED
    assert engine.metrics.counter("serving/shed").value == 1
    # the downshift is visible in the per-tick decisions: decode/base until
    # the third breach, decode/fallback while degraded
    plans = [d.plan for d in engine.scheduler.decisions]
    assert plans[:3] == ["decode/base"] * 3
    assert "decode/fallback" in plans[3:]
    # three healthy ticks after the burst step the ladder back up
    assert engine.scheduler.level == 0
    assert engine.pool.stats.buffers_built == 1


# ---------------------------------------------------------------------------
# The chaos property (hypothesis in CI, fixed seeds everywhere)
# ---------------------------------------------------------------------------
def _chaos_property(tiny, baseline, seed):
    """Any seeded FaultPlan: no exception escapes stream(), every request
    terminates with a reason from the closed set, healthy lanes match the
    fault-free run token-for-token, and the pool never reallocates."""
    cfg, model, params = tiny
    reqs = _requests(cfg)
    faults = FaultPlan.seeded(seed, n_slots=2, ticks=10,
                              uids=tuple(r.uid for r in reqs),
                              n_poison=2, n_prefill=1, n_slow_burst=1,
                              slow_extra_s=0.01, n_flood=1, flood_n=2)
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=4, faults=faults, retry_budget=1)
    for ev in engine.stream(reqs):
        assert ev.finish_reason is None or ev.finish_reason in FINISH_REASONS
    done = engine.take_finished()
    for req in reqs:
        assert req.uid in done, f"request {req.uid} never terminated"
        res = done[req.uid]
        assert res.finish_reason in FINISH_REASONS
        if res.finish_reason == FinishReason.LENGTH:
            np.testing.assert_array_equal(res.tokens, baseline[req.uid])
    assert engine.pool.stats.buffers_built == 1
    assert engine._scratch_pool.stats.buffers_built == 1


@pytest.mark.parametrize("seed", [0, 3])
def test_chaos_fixed_seeds(tiny, baseline, seed):
    _chaos_property(tiny, baseline, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16))
    def test_chaos_property_hypothesis(tiny, baseline, seed):
        _chaos_property(tiny, baseline, seed)
else:                                       # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chaos_property_hypothesis():
        pass
