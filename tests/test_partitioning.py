"""Logical-axis sharding rules: divisibility fallback, spec resolution."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import partitioning as pt


def _mesh(shape=(1, 1), axes=("data", "model")):
    devs = np.array(jax.devices()[:1]).reshape((1,) * len(shape))
    devs = np.broadcast_to(devs, tuple(1 for _ in shape))
    return Mesh(devs, axes)


def _fake_mesh(data=16, model=16, pod=None):
    """Mesh object with arbitrary logical sizes for rule resolution tests
    (never used to place data)."""
    class FakeMesh:
        def __init__(self):
            names = (("pod", "data", "model") if pod else ("data", "model"))
            sizes = ((pod, data, model) if pod else (data, model))
            self.shape = dict(zip(names, sizes))
    return FakeMesh()


def rules(**kw):
    return pt.AxisRules(rules=pt.DEFAULT_RULES, mesh=_fake_mesh(**kw))


def test_basic_resolution():
    r = rules()
    assert r.spec_for(("embed", "mlp"), (1024, 4096)) == P("data", "model")
    assert r.spec_for(("batch", "seq"), (256, 4096)) == P("data")


def test_divisibility_fallback():
    r = rules()
    # 14 heads cannot shard over a 16-way model axis -> replicated
    assert r.spec_for(("embed", "heads", None), (896, 14, 64)) == P("data")
    # but d_ff = 4864 = 16*304 still shards
    assert r.spec_for(("embed", "mlp"), (896, 4864)) == P("data", "model")


def test_multi_axis_batch():
    r = rules(pod=2)
    assert r.spec_for(("batch", "seq"), (256, 128)) == P(("pod", "data"))
    # batch=1 (long_500k): falls back to replicated
    assert r.spec_for(("batch", "seq"), (1, 128)) == P()


def test_no_double_use_of_mesh_axis():
    r = rules()
    # cache axes: cache_seq takes 'model' first, kv_heads then can't
    spec = r.spec_for(("layers", "batch", "cache_seq", "kv_heads", None),
                      (4, 128, 32768, 16, 128))
    assert spec == P(None, "data", "model")


def test_partial_multi_axis_divisibility():
    r = rules(pod=2)
    # batch=32 divisible by pod*data=32 -> both axes
    assert r.spec_for(("batch",), (32,)) == P(("pod", "data"))
    # batch=16 not divisible by 32 -> drop trailing axis, keep pod:
    # ('pod','data') -> trailing dropped gives ('pod',), 16 % 2 == 0,
    # and spec_for unwraps singleton axis tuples to the bare axis name
    assert r.spec_for(("batch",), (16,)) == P("pod")


def test_constrain_is_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert pt.constrain(x, ("batch", None)) is x


def test_annot_roundtrip_through_eval_shape():
    import jax.numpy as jnp

    def init():
        return {"w": pt.Annot(jnp.zeros((4, 8)), ("embed", "mlp"))}

    abs_tree = jax.eval_shape(init)
    vals, axes = pt.split(abs_tree)
    assert vals["w"].shape == (4, 8)
    assert axes["w"] == ("embed", "mlp")


def test_annot_rank_mismatch_raises():
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        pt.Annot(jnp.zeros((4, 8)), ("embed",))
