"""launch/train.py driver edge cases: ``--log-every 0`` must not divide by
zero and ``--steps 0`` must not index an empty history (both crashed the
driver before PR 3)."""
import sys

import pytest

from repro.launch import train as train_mod


def _run(capsys, monkeypatch, *argv):
    monkeypatch.setattr(sys, "argv", ["train.py", *argv])
    train_mod.main()
    return capsys.readouterr().out


def test_steps_zero_empty_history(capsys, monkeypatch):
    out = _run(capsys, monkeypatch,
               "--arch", "qwen2-0.5b", "--reduced", "--steps", "0",
               "--batch", "1", "--seq", "8")
    assert "no training steps run" in out
    assert "loss" not in out.splitlines()[-1]


@pytest.mark.slow
def test_log_every_zero_logs_every_step(capsys, monkeypatch):
    out = _run(capsys, monkeypatch,
               "--arch", "qwen2-0.5b", "--reduced", "--steps", "2",
               "--batch", "1", "--seq", "8", "--log-every", "0")
    # clamped to 1: both steps logged, summary printed
    assert out.count('"step"') == 2
    assert "loss" in out.splitlines()[-1]
