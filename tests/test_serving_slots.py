"""Slot-resident continuous batching (serving/slots.py + SlotEngine).

The fast tests drive a micro dense model (2 layers, d=64) — they are the
quick-loop serving smoke.  The per-family slot-vs-wave equivalence sweeps
build full reduced() archs and carry the ``slow`` marker.
"""
import dataclasses
import gc

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import registry
from repro.partitioning import split
from repro.serving import (Engine, EngineConfig, QueueFull, Request,
                           RequestQueue, SlotEngine, chunk_schedule)


@pytest.fixture(autouse=True)
def _release_compiled_state():
    # Engines are built per-test, so their jit closures (and the XLA
    # executables behind them) are garbage after each test.  Dropping them
    # eagerly keeps the long-lived suite process from accumulating native
    # compiler state across the many engine constructions in this module.
    yield
    gc.collect()
    jax.clear_caches()


def _tiny_cfg():
    return dataclasses.replace(
        get_arch("qwen2-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=128, vocab=128)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    return cfg, model, params


def _requests(cfg, lens, news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab, (int(l),)).astype(np.int32),
                    max_new_tokens=int(m))
            for i, (l, m) in enumerate(zip(lens, news))]


class FakeClock:
    """Deterministic monotonic clock: advances 1.0 per call."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# Queue (no model)
# ---------------------------------------------------------------------------
def test_queue_fifo_and_backpressure():
    q = RequestQueue(capacity=2)
    a = Request(0, np.array([1], np.int32))
    b = Request(1, np.array([2], np.int32))
    q.submit(a)
    q.submit(b)
    assert q.full and len(q) == 2
    with pytest.raises(QueueFull, match="full"):
        q.submit(Request(2, np.array([3], np.int32)))
    assert q.pop() is a          # FIFO
    assert q.pop() is b
    assert q.pop() is None


def test_queue_expiry_with_duplicate_uids_and_equal_prompts():
    """Regression: expiry partitions by identity — dataclass ``==`` over
    ndarray prompts would raise 'truth value of an array is ambiguous'."""
    clock = FakeClock()
    q = RequestQueue(capacity=4, clock=clock)
    # no deadline -> no clock call; the second submit sees clock=1.0, so a
    # deadline of 1.5 is still live at submit but dead at the expire sweep
    assert q.submit(Request(5, np.array([1, 2, 3], np.int32)))
    assert q.submit(Request(5, np.array([1, 2, 3], np.int32), deadline_s=1.5))
    expired = q.expire()                                  # clock -> 2.0
    assert len(expired) == 1 and expired[0].deadline_s == 1.5
    assert len(q) == 1 and q.pop().deadline_s is None


def test_queue_deadline_expiry():
    clock = FakeClock()
    q = RequestQueue(capacity=4, clock=clock)
    # already-passed deadline is dead on arrival: rejected at submit (no
    # dead work queued until the next expiry sweep), False returned
    assert not q.submit(Request(0, np.array([1], np.int32), deadline_s=0.5))
    assert len(q) == 0
    assert q.submit(Request(1, np.array([2], np.int32), deadline_s=2.5))
    assert q.submit(Request(2, np.array([3], np.int32)))            # none
    expired = q.expire()                                  # clock -> 3.0
    assert [r.uid for r in expired] == [1]
    assert len(q) == 1 and q.pop().uid == 2


# ---------------------------------------------------------------------------
# Slot engine (quick-loop serving smoke: tiny config, 8 requests)
# ---------------------------------------------------------------------------
def test_slot_engine_smoke_mixed_max_new(tiny):
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=3, max_seq=64,
                        queue_capacity=4)
    reqs = _requests(cfg, [5, 9, 3, 7, 5, 9, 3, 7], [2, 8, 4, 6, 8, 1, 6, 4])
    events = []
    results = engine.serve(reqs, on_token=events.append)
    assert [r.uid for r in results] == list(range(8))
    for r, req in zip(results, reqs):
        assert r.finish_reason == "length"
        assert r.tokens.shape == (req.max_new_tokens,)
    # streamed events reassemble into exactly the returned tokens
    for req, res in zip(reqs, results):
        toks = [ev.token for ev in events if ev.uid == req.uid]
        assert np.array_equal(np.stack(toks, -1), res.tokens)
        dones = [ev.done for ev in events if ev.uid == req.uid]
        assert sum(dones) == 1 and dones[-1]
    # uid 0 (2 tokens) must retire before uid 1 (8 tokens) completes
    order = [ev.uid for ev in events if ev.done]
    assert order.index(0) < order.index(1)
    # no serving-path allocation: both pools keep their build-time buffers
    assert engine.pool.stats.buffers_built == engine.pool.stats.capacity == 1
    assert engine._scratch_pool.stats.buffers_built == 1


def test_slot_engine_no_alloc_after_warmup(tiny):
    import gc

    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=64)
    engine.serve(_requests(cfg, [4, 6, 4], [3, 2, 4]))           # warmup
    gc.collect()
    live0 = len(jax.live_arrays())
    engine.serve(_requests(cfg, [4, 6, 4], [2, 4, 3], seed=1))
    gc.collect()
    # the live device-buffer population does not grow across a warm serve
    # — every serving-path update runs through a donated jit in place
    assert len(jax.live_arrays()) <= live0
    assert (engine.pool.stats.buffers_built,
            engine._scratch_pool.stats.buffers_built) == (1, 1)
    # ONE resident + ONE scratch checkout for the engine's whole life: the
    # scratch is zeroed in place inside the donated prefill jit, never
    # returned/rebuilt
    assert engine.pool.stats.checkouts == 1
    assert engine._scratch_pool.stats.checkouts == 1
    # the always-on serving metrics are host-side ints/deques — populating
    # them across two serves must not have touched the device pools above
    assert engine.metrics.counter("serving/ticks").value > 0
    assert engine.metrics.histogram("serving/ttft_s").count == 6


def test_slot_engine_traced_run_token_identical(tiny):
    """Tracing on vs off must not change a single token or allocate on
    the serving path, and the trace must carry per-tick spans with the
    chosen plan, TTFT admit events, and nested sched/choose decisions."""
    from repro.obs import ListSink, Tracer, set_tracer

    cfg, model, params = tiny
    reqs = lambda: _requests(cfg, [4, 6, 3], [3, 2, 4])
    base = SlotEngine(model, params, n_slots=2, max_seq=64)
    want = [r.tokens for r in base.serve(reqs())]

    sink = ListSink()
    old = set_tracer(Tracer(sink))
    try:
        traced = SlotEngine(model, params, n_slots=2, max_seq=64)
        got = [r.tokens for r in traced.serve(reqs())]
    finally:
        set_tracer(old)

    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert traced.pool.stats.buffers_built == 1       # zero-alloc holds

    ticks = [r for r in sink.records if r["name"] == "serve/tick"]
    admits = [r for r in sink.records if r["name"] == "serve/admit"]
    chooses = [r for r in sink.records if r["name"] == "sched/choose"]
    assert ticks and len(admits) == 3
    tick_ids = {r["span"] for r in ticks}
    for t in ticks:
        assert t["type"] == "span" and t["attrs"]["plan"]
        assert t["attrs"]["tick_s"] > 0
    for a in admits:
        assert a["attrs"]["ttft_s"] > 0
    # every per-tick plan decision nests under its tick span
    assert chooses and all(c["parent"] in tick_ids for c in chooses)
    # the run closes with a metrics summary event
    summaries = [r for r in sink.records if r["name"] == "serve/metrics"]
    assert summaries
    snap = summaries[-1]["attrs"]
    assert snap["counters"]["serving/deadline_miss"] == 0
    assert snap["counters"]["serving/retired"] == 3
    assert snap["histograms"]["serving/ttft_s"]["count"] == 3


def test_slot_engine_ttft_on_results(tiny):
    """Satellite: per-request TTFT (admit -> first token on host) rides on
    Result next to decode_s, and feeds the serving/ttft_s histogram."""
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=64)
    results = engine.serve(_requests(cfg, [4, 7, 3], [3, 2, 4]))
    for r in results:
        assert r.finish_reason == "length"
        assert r.ttft_s > 0.0
        # the first token is produced AT admission, before any decode tick
        assert r.ttft_s <= r.prefill_s + r.decode_s + 1.0
    h = engine.metrics.histogram("serving/ttft_s")
    assert h.count == len(results)
    assert engine.metrics.histogram("serving/tbt_s").count > 0
    assert engine.metrics.counter("serving/retired").value == len(results)
    # zero-alloc invariant holds with metrics populated
    assert engine.pool.stats.buffers_built == 1


def test_slot_engine_backpressure(tiny):
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=1, max_seq=64,
                        queue_capacity=2)
    engine.submit(Request(0, np.array([1, 2], np.int32), max_new_tokens=2))
    engine.submit(Request(1, np.array([3], np.int32), max_new_tokens=2))
    with pytest.raises(QueueFull):
        engine.submit(Request(2, np.array([4], np.int32)))
    # drain what was accepted
    for _ in engine.stream():
        pass
    assert sorted(engine.finished) == [0, 1]


def test_deadline_expiry_queued_and_resident(tiny):
    cfg, model, params = tiny
    clock = FakeClock()
    engine = SlotEngine(model, params, n_slots=1, max_seq=64, clock=clock)
    reqs = [
        # admitted first; deadline hits mid-generation (clock ticks ~1/loop)
        Request(0, np.array([1, 2, 3], np.int32), max_new_tokens=32,
                deadline_s=6.0),
        # waits behind uid 0 in the single slot; already past its deadline
        # by the time the loop re-checks the queue
        Request(1, np.array([4, 5], np.int32), max_new_tokens=2,
                deadline_s=0.5),
        # no deadline: must still complete fully
        Request(2, np.array([6], np.int32), max_new_tokens=3),
    ]
    results = engine.serve(reqs)
    assert results[0].finish_reason == "deadline"
    assert 0 < results[0].tokens.shape[-1] < 32     # partial output surfaced
    assert results[1].finish_reason == "deadline"
    assert results[1].tokens.shape[-1] == 0         # dropped from the queue
    assert results[2].finish_reason == "length"
    assert results[2].tokens.shape[-1] == 3


def test_zero_budget_request_gets_zero_tokens(tiny):
    """max_new_tokens=0 completes without prefilling or occupying a lane —
    matching the wave engine's per-request truncation."""
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=64)
    reqs = [Request(0, np.array([1, 2], np.int32), max_new_tokens=0),
            Request(1, np.array([3, 4], np.int32), max_new_tokens=2)]
    results = engine.serve(reqs)
    assert results[0].tokens.shape == (0,)
    assert results[0].finish_reason == "length"
    assert results[1].tokens.shape == (2,)


def test_deadline_checked_on_mid_admission_refill(tiny):
    """Regression: a request that only reaches the queue during the
    admission loop's refill (queue was full at loop top) must still be
    deadline-checked, not silently served."""
    cfg, model, params = tiny
    clock = FakeClock()
    engine = SlotEngine(model, params, n_slots=2, max_seq=64,
                        queue_capacity=1, clock=clock)
    reqs = [Request(0, np.array([1, 2], np.int32), max_new_tokens=1),
            Request(1, np.array([3], np.int32), max_new_tokens=1,
                    deadline_s=0.5)]        # already past at first tick
    results = engine.serve(reqs)
    assert results[0].finish_reason == "length"
    assert results[1].finish_reason == "deadline"
    assert results[1].tokens.shape[-1] == 0


def test_request_exceeding_lane_budget_rejected_upfront(tiny):
    """prompt_len + max_new_tokens - 1 > max_seq would scatter decode KV
    out of range (silently dropped) — rejected at submit time instead."""
    cfg, model, params = tiny
    engine = SlotEngine(model, params, n_slots=2, max_seq=16)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        engine.submit(Request(0, np.arange(12, dtype=np.int32),
                              max_new_tokens=8))
    with pytest.raises(ValueError, match="exceeds max_seq"):
        engine.serve([Request(0, np.arange(12, dtype=np.int32),
                              max_new_tokens=8)])
    # exactly at the budget is fine: positions 11 + 0..5 < 16
    res = engine.serve([Request(0, np.arange(12, dtype=np.int32),
                                max_new_tokens=5)])
    assert res[0].tokens.shape == (5,)


def test_wave_engine_pads_with_inactive_dummies(tiny):
    """Ragged wave tails pad with zero-length dummy requests, not
    duplicates of real work; every request gets ITS OWN token budget."""
    cfg, model, params = tiny
    engine = Engine(model, params, batch_size=4, max_seq=64,
                    pool_capacity=1)
    reqs = _requests(cfg, [6, 6, 6, 6, 6], [4, 2, 4, 4, 3])
    results = engine.serve(reqs)
    assert [r.uid for r in results] == [0, 1, 2, 3, 4]
    assert [r.tokens.shape[-1] for r in results] == [4, 2, 4, 4, 3]


# ---------------------------------------------------------------------------
# EngineConfig (consolidated construction surface + deprecated aliases)
# ---------------------------------------------------------------------------
def test_engine_config_aliases_warn_and_match(tiny):
    cfg, model, params = tiny
    with pytest.warns(DeprecationWarning, match="deprecated"):
        legacy = SlotEngine(model, params, n_slots=3, max_seq=64,
                            queue_capacity=4, retry_budget=1)
    modern = SlotEngine(model, params, config=EngineConfig(
        n_slots=3, max_seq=64, queue_capacity=4, retry_budget=1))
    assert legacy.config == modern.config
    assert (legacy.n_slots, legacy.max_seq, legacy.retry_budget) == (3, 64, 1)
    # behaviour, not just bookkeeping: same tokens either way
    want = [r.tokens for r in modern.serve(_requests(cfg, [4, 6], [3, 2]))]
    got = [r.tokens for r in legacy.serve(_requests(cfg, [4, 6], [3, 2]))]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # wave engine: batch_size is the alias of n_slots
    with pytest.warns(DeprecationWarning, match="deprecated"):
        wave = Engine(model, params, batch_size=2, max_seq=32,
                      pool_capacity=1)
    assert wave.config.n_slots == wave.config.batch_size == 2


def test_engine_config_rejects_mixed_and_unknown(tiny):
    cfg, model, params = tiny
    with pytest.raises(ValueError, match="not both"):
        SlotEngine(model, params, config=EngineConfig(), n_slots=2)
    with pytest.raises(TypeError, match="unexpected keyword"):
        SlotEngine(model, params, bogus_knob=2)
    with pytest.raises(TypeError, match="unexpected keyword"):
        Engine(model, params, n_slots=2)      # a slot-only spelling


# ---------------------------------------------------------------------------
# Chunked prefill (EngineConfig.prefill_chunk_len — admission interleaving)
# ---------------------------------------------------------------------------
def test_chunk_schedule_fixed_shapes():
    # full chunks then the remainder's binary decomposition, descending
    assert chunk_schedule(13, 8) == [8, 4, 1]
    assert chunk_schedule(24, 8) == [8, 8, 8]
    assert chunk_schedule(7, 8) == [4, 2, 1]
    assert chunk_schedule(1, 8) == [1]
    assert chunk_schedule(0, 8) == []
    with pytest.raises(ValueError):
        chunk_schedule(4, 0)
    # the compiled-shape bound: whatever the prompt mix, segment lengths
    # come from {chunk_len} U {powers of two below it}
    allowed = {8, 4, 2, 1}
    for s in range(1, 70):
        segs = chunk_schedule(s, 8)
        assert sum(segs) == s and set(segs) <= allowed


def test_chunked_prefill_token_identity_and_one_shape(tiny):
    """Chunking changes scheduling, not math: greedy tokens match
    whole-prompt admission bit-for-bit, the chunk jit compiles exactly one
    executable per DISTINCT segment length, and both pools keep their
    build-time buffers through checkout/give_back lane churn."""
    cfg, model, params = tiny
    lens, news = [5, 29, 3, 13, 7, 21], [4, 6, 3, 5, 2, 4]
    whole = SlotEngine(model, params, config=EngineConfig(
        n_slots=3, max_seq=64))
    want = whole.serve(_requests(cfg, lens, news, seed=3))

    engine = SlotEngine(model, params, config=EngineConfig(
        n_slots=3, max_seq=64, prefill_chunk_len=8, prefill_lanes=2))
    got = engine.serve(_requests(cfg, lens, news, seed=3))
    for w, g in zip(want, got):
        assert g.finish_reason == "length"
        np.testing.assert_array_equal(w.tokens, g.tokens)
    segs = set()
    for l in lens:
        segs.update(chunk_schedule(l, 8))
    assert engine._prefill_chunk._cache_size() == len(segs)
    assert engine.pool.stats.buffers_built == 1
    sp = engine._scratch_pool.stats
    assert sp.buffers_built == sp.capacity == 2       # == prefill_lanes
    assert sp.outstanding == 0                        # every lane released
    assert engine.metrics.histogram("serving/prefill_chunk_s").count == \
        sum(len(chunk_schedule(l, 8)) for l in lens)


def test_decode_continues_during_chunked_prefill(tiny):
    """The headline scheduling property: a resident short request keeps
    producing decode tokens while a long-prompt adversary prefills in
    chunks — admission stalls the tick loop by at most one chunk, not the
    adversary's whole prefill."""
    cfg, model, params = tiny
    engine = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=64, queue_capacity=4, prefill_chunk_len=4,
        prefill_lanes=2))
    short = Request(0, np.arange(1, 5, dtype=np.int32), max_new_tokens=12)
    adversary = Request(1, np.arange(1, 25, dtype=np.int32),  # 6 chunks
                        max_new_tokens=2)
    events = []
    results = engine.serve([short, adversary], on_token=events.append)
    uids = [ev.uid for ev in events if ev.token is not None]
    first_adv = uids.index(1)
    # the short request decoded through the adversary's whole chunked
    # prefill: several of its tokens land BEFORE the adversary's first
    assert uids[:first_adv].count(0) >= 5
    assert all(r.finish_reason == "length" for r in results)


def test_partial_prefill_abort_keeps_pool_at_capacity(tiny):
    """A deadline that lands mid-chunked-prefill aborts the lane: the
    partial state is discarded through the pool's donated reset
    (buffers_built untouched) and later requests are served normally."""
    cfg, model, params = tiny
    clock = FakeClock()
    engine = SlotEngine(model, params, clock=clock, config=EngineConfig(
        n_slots=1, max_seq=64, queue_capacity=4, prefill_chunk_len=4,
        prefill_lanes=1))
    doomed = Request(0, np.arange(1, 41, dtype=np.int32),   # 10 chunks
                     max_new_tokens=4, deadline_s=4.0)      # dies mid-prefill
    healthy = Request(1, np.arange(1, 7, dtype=np.int32), max_new_tokens=3)
    results = engine.serve([doomed, healthy])
    assert results[0].finish_reason == "deadline"
    assert results[0].tokens.shape[-1] == 0
    assert results[1].finish_reason == "length"
    assert results[1].tokens.shape == (3,)
    sp = engine._scratch_pool.stats
    assert sp.buffers_built == sp.capacity == 1
    assert sp.outstanding == 0
    assert engine.pool.stats.buffers_built == 1
    assert engine.metrics.counter("serving/deadline_miss").value == 1


def test_chunked_rejects_invalid_config(tiny):
    cfg, model, params = tiny
    with pytest.raises(ValueError, match="prefill_chunk_len"):
        SlotEngine(model, params, config=EngineConfig(
            n_slots=2, max_seq=64, prefill_chunk_len=65))
    with pytest.raises(ValueError, match="prefill_lanes"):
        SlotEngine(model, params, config=EngineConfig(
            n_slots=2, max_seq=64, prefill_chunk_len=4, prefill_lanes=0))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-0.5b",            # dense
                                  "jamba-1.5-large-398b",  # ssm (mamba)
                                  "rwkv6-3b"])             # rwkv
def test_chunked_prefill_token_identity_per_family(arch):
    """Chunked admission is token-identical to whole-prompt admission for
    every serving family — attention replays the exact positions through
    the chunk mask, rwkv/mamba prefill FROM their cache state natively."""
    cfg = get_arch(arch).reduced()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    reqs = _requests(cfg, [4, 10, 6, 8], [3, 8, 2, 5], seed=1)
    whole = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=32)).serve(reqs)
    chunked = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=32, prefill_chunk_len=4,
        prefill_lanes=2)).serve(reqs)
    for w, g in zip(whole, chunked):
        assert np.array_equal(w.tokens, g.tokens), (w.uid, w.tokens,
                                                    g.tokens)


# ---------------------------------------------------------------------------
# Slot-vs-wave greedy equivalence per model family
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-0.5b",            # dense
                                  "jamba-1.5-large-398b",  # ssm (mamba)
                                  "rwkv6-3b"])             # rwkv
def test_slot_vs_wave_equivalence(arch):
    """The slot engine's greedy outputs are token-identical to the
    unpadded per-request reference (the wave engine at batch_size=1) on a
    ragged request set — per-lane prefill, per-lane positions and the
    active-mask select are all exact."""
    cfg = get_arch(arch).reduced()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    reqs = _requests(cfg, [4, 10, 6, 8], [3, 8, 2, 5], seed=1)
    ref = Engine(model, params, batch_size=1, max_seq=32,
                 pool_capacity=1).serve(reqs)
    out = SlotEngine(model, params, n_slots=2, max_seq=32).serve(reqs)
    for r, o in zip(ref, out):
        assert np.array_equal(r.tokens, o.tokens), (r.uid, r.tokens,
                                                    o.tokens)
