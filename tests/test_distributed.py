"""Distributed-path correctness: the shard_map expert-parallel MoE and the
sequence-parallel wkv pipeline must equal their single-device references.

These need >1 XLA device, so they run in a subprocess with
``--xla_force_host_platform_device_count=8`` (the main test process must
keep the single real device; see conftest note)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# multi-second integration sweeps: excluded from the quick loop (-m "not slow")
pytestmark = pytest.mark.slow


def run_in_devices(code: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]


MOE_EP = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import moe as moe_lib
from repro.partitioning import split, make_rules, use_rules
cfg = get_arch('olmoe-1b-7b').reduced()
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = make_rules(mesh)
p, _ = split(moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
with mesh, use_rules(rules):
    out_ep, _ = jax.jit(lambda p, x: moe_lib.apply_moe(p, x, cfg,
                                                       no_drop=True))(p, x)
out_d, _ = moe_lib._apply_moe_dense(p, x, cfg, True)
np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_d),
                           rtol=2e-4, atol=2e-4)
def le(p):
    with mesh, use_rules(rules):
        o, _ = moe_lib.apply_moe(p, x, cfg, no_drop=True)
    return jnp.sum(o ** 2)
def ld(p):
    o, _ = moe_lib._apply_moe_dense(p, x, cfg, True)
    return jnp.sum(o ** 2)
g1, g2 = jax.jit(jax.grad(le))(p), jax.grad(ld)(p)
for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-4, atol=3e-4)
print('ok')
"""

SEQPAR = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import rwkv
from repro.partitioning import split, make_rules, use_rules
cfg = get_arch('rwkv6-3b').reduced()
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = make_rules(mesh)
p, _ = split(rwkv.init_tmix(jax.random.PRNGKey(0), cfg, jnp.float32))
B, S, d = 4, 32, cfg.d_model
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.5
xp = jax.random.normal(jax.random.PRNGKey(2), (B, d)) * 0.5
H, dh = rwkv.n_heads(cfg), cfg.ssm.head_dim
s0 = jax.random.normal(jax.random.PRNGKey(3), (B, H, dh, dh)) * 0.3
o1, sh1, st1 = rwkv._apply_tmix_local(p, cfg, x, xp, s0)
with mesh, use_rules(rules):
    o2, sh2, st2 = jax.jit(lambda p, x, xp, s0: rwkv.apply_tmix(
        p, cfg, x, xp, s0))(p, x, xp, s0)
np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=4e-4,
                           atol=4e-4)
np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=4e-4,
                           atol=4e-4)
np.testing.assert_allclose(np.asarray(sh1), np.asarray(sh2), rtol=1e-5,
                           atol=1e-5)
print('ok')
"""

FULL_MODEL_SEQPAR = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import registry
from repro.configs.base import ShapeConfig
from repro.partitioning import split, make_rules, use_rules, tree_shardings
cfg = get_arch('rwkv6-3b').reduced()
m = registry.build(cfg)
params, axes = split(m.init(jax.random.PRNGKey(0)))
batch = registry.make_batch(cfg, ShapeConfig('s', 32, 4, 'train'),
                            jax.random.PRNGKey(1))
logits_1dev, _ = m.forward(params, batch)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
rules = make_rules(mesh)
with mesh, use_rules(rules):
    logits_dist, _ = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
np.testing.assert_allclose(np.asarray(logits_1dev, np.float32),
                           np.asarray(logits_dist, np.float32),
                           rtol=3e-3, atol=3e-3)
print('ok')
"""


@pytest.mark.parametrize("name,code", [
    ("moe_expert_parallel", MOE_EP),
    ("rwkv_seq_parallel", SEQPAR),
    ("rwkv_full_model_dist_equals_local", FULL_MODEL_SEQPAR),
])
def test_distributed(name, code):
    run_in_devices(code)
