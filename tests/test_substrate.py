"""Data pipeline, optimizer, checkpointing, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import SyntheticLM, har
from repro.optim import AdamW, warmup_cosine


def test_har_shapes_and_balance():
    train, test = har.make_har(n_train=600, n_test=120, seed=0)
    assert train.x.shape == (600, 128, 9)
    assert test.y.shape == (120,)
    assert set(np.unique(train.y)) <= set(range(6))
    counts = np.bincount(train.y, minlength=6)
    assert counts.min() > 0


def test_har_classes_are_separable_by_simple_stats():
    """Laying must differ from walking in gravity orientation & dynamics."""
    train, _ = har.make_har(n_train=400, n_test=10, seed=1)
    walking = train.x[train.y == 0]
    laying = train.x[train.y == 5]
    if len(walking) and len(laying):
        walk_dyn = np.abs(walking[:, :, :3]).mean()
        lay_dyn = np.abs(laying[:, :, :3]).mean()
        assert walk_dyn > 3 * lay_dyn


def test_har_batches_iterate():
    train, _ = har.make_har(n_train=100, n_test=10)
    it = har.batches(train, 16, epochs=1)
    xs, ys = next(it)
    assert xs.shape == (16, 128, 9) and ys.shape == (16,)


def test_synthetic_lm_structure():
    lm = SyntheticLM(vocab=97, seed=0)
    rng = np.random.default_rng(0)
    toks = lm.sample(rng, 4, 64)
    assert toks.shape == (4, 64)
    assert toks.min() >= 0 and toks.max() < 97
    # determinstic component: a*prev + prev2 + b appears often
    det = (lm.a * toks[:, 1:-1] + toks[:, :-2] + lm.b) % 97
    frac = (det == toks[:, 2:]).mean()
    assert frac > 0.4


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2
    assert int(state["step"]) == 100


def test_adamw_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 1.0   # reported pre-clip


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, warmup=10, total=100)
    assert float(fn(jnp.asarray(5))) < 1.0
    assert abs(float(fn(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(fn(jnp.asarray(100))) < float(fn(jnp.asarray(50)))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.ones((4,), jnp.int32)}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = ckpt.restore(str(tmp_path), template)
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(back["c"], tree["c"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), {"w": jnp.zeros((3, 3))})


def test_checkpoint_picks_latest(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.zeros(1)})
    ckpt.save(str(tmp_path), 12, {"w": jnp.ones(1)})
    back = ckpt.restore(str(tmp_path), {"w": jnp.zeros(1)})
    assert float(back["w"][0]) == 1.0


# ---------------------------------------------------------------------------
def test_serving_engine_end_to_end():
    from repro.configs import get_arch
    from repro.models import registry
    from repro.partitioning import split
    from repro.serving import Engine, Request

    cfg = get_arch("qwen2-0.5b").reduced()
    m = registry.build(cfg)
    params, _ = split(m.init(jax.random.PRNGKey(0)))
    eng = Engine(m, params, batch_size=2, max_seq=32, pool_capacity=2)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, (6,)).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    res = eng.serve(reqs)
    assert len(res) == 3
    assert all(r.tokens.shape == (4,) for r in res)
    assert eng.pool.stats.outstanding == 0
    assert eng.pool.stats.checkouts == 2   # two waves


@pytest.mark.slow
def test_serving_engine_greedy_matches_manual_decode():
    """Engine output == manual prefill+greedy loop with the raw model."""
    from repro.configs import get_arch
    from repro.models import registry
    from repro.partitioning import split
    from repro.serving import Engine, Request
    from repro import steps

    cfg = get_arch("yi-9b").reduced()
    m = registry.build(cfg)
    params, _ = split(m.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, (5,)).astype(np.int32)

    eng = Engine(m, params, batch_size=1, max_seq=16)
    out = eng.serve([Request(0, prompt, max_new_tokens=3)])[0].tokens

    cache, _ = split(m.init_cache(1, 16))
    logits, cache = m.prefill(params, cache, {"tokens": prompt[None]})
    toks = []
    tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    for _ in range(3):
        toks.append(int(tok[0]))
        logits, cache = m.decode_step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.array(toks))
