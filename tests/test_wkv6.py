"""Property-based tests (hypothesis) on the hardened WKV6 chunked-scan
kernel (kernels/wkv6.py) — the rwkv6 family's fused fast path.

Three invariants the chunk-size decision and the log-space formulation are
supposed to buy:

* every decay exponent the kernel ever exponentiates is a difference of
  log-decay cumsums with the later index subtracted — <= 0 by
  construction, so exp never overflows no matter how strong the decay;
* outputs and the carried state stay FINITE under extreme decay
  magnitudes and mixed input dtypes (bf16 r/k/v over the f32 log-decays);
* the scan is a monoid over the carried state: splitting a sequence at an
  ARBITRARY boundary and resuming from the returned state reproduces the
  unsplit run — the serving contract (kv-state handoff between requests)
  and, because the pieces rarely divide the chunk, a standing exercise of
  the identity zero-padding path.

hypothesis is an OPTIONAL dev dependency (requirements-dev.txt); without
it this module must skip at collection, not kill the tier-1 run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import wkv6 as wkv6_lib  # noqa: E402

SETTINGS = dict(max_examples=15, deadline=None)


def _inputs(T, dk, dv, seed, decay_scale=1.0, dtype=jnp.float32, BH=2):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (BH, T, dk), dtype)
    k = jax.random.normal(ks[1], (BH, T, dk), dtype)
    v = jax.random.normal(ks[2], (BH, T, dv), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, T, dk))) * decay_scale
    u = jax.random.normal(ks[4], (BH, dk))
    s0 = jax.random.normal(ks[5], (BH, dk, dv)) * 0.3
    return r, k, v, logw, u, s0


# ---------------------------------------------------------------------------
# exponent sign: everything under exp is <= 0 by construction
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-3, 1e3))
def test_decay_exponents_nonpositive(C, seed, decay_scale):
    """The three exponent families of the chunk math — L_prev itself (the
    carry term), the masked intra-chunk differences L_prev[i] - L[j] for
    j < i, and the state-update differences L_last - L — are <= 0 whenever
    logw <= 0, at any chunk size and decay magnitude, up to cumsum
    rounding: entries that are mathematically empty sums (j = i-1) are
    computed as differences of two large nearly-equal cumsums, so they may
    carry a few ulps of |L| above zero — which keeps exp at O(1) instead
    of overflowing, the property the kernel actually needs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 1)[0]
    logw = -jnp.exp(jax.random.normal(ks, (C, 4))) * decay_scale
    L = jnp.cumsum(logw, axis=0)
    L_prev = L - logw
    slack = 64 * jnp.finfo(jnp.float32).eps * jnp.maximum(
        jnp.max(jnp.abs(L)), 1.0)
    assert bool(jnp.all(L_prev <= slack))
    diff = L_prev[:, None, :] - L[None, :, :]
    mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[:, :, None]
    assert bool(jnp.all(jnp.where(mask, diff, 0.0) <= slack))
    assert bool(jnp.all(L[-1][None, :] - L <= slack))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1.0, 10.0, 1e3, 1e6]),
       st.sampled_from(["float32", "bfloat16"]))
def test_outputs_finite_under_extreme_decay(seed, decay_scale, dtype):
    """No inf/nan from the kernel even when single-step log-decays reach
    -1e6 (state effectively zeroed every step) or inputs are bf16: the
    log-space differences keep every exponent <= 0, so exp underflows to 0
    instead of overflowing."""
    T, dk, dv = 19, 8, 8      # non-dividing T: the pad path is in the loop
    r, k, v, logw, u, s0 = _inputs(T, dk, dv, seed, decay_scale,
                                   jnp.dtype(dtype))
    out, s_out = wkv6_lib.wkv6(r, k, v, logw, u, s0, chunk=8)
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert bool(jnp.all(jnp.isfinite(s_out)))


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_grads_finite_under_extreme_decay(seed):
    """The fused reverse sweep inherits the same exponent bound (its
    jax.vjp re-linearises the identical chunk math), so training gradients
    stay finite under strong decay too."""
    T, dk, dv = 13, 4, 4
    args = _inputs(T, dk, dv, seed, decay_scale=1e3)

    def loss(*a):
        out, s = wkv6_lib.wkv6(*a, chunk=4)
        return jnp.sum(jnp.tanh(out.astype(jnp.float32))) + jnp.sum(s * s)

    grads = jax.grad(loss, argnums=tuple(range(6)))(*args)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# state carry: split anywhere, resume from the returned state
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, 22), st.integers(0, 2 ** 31 - 1),
       st.integers(1, 12))
def test_split_resume_matches_unsplit(split, seed, chunk):
    """wkv6 over [0:t) then [t:T) with the state handed across equals one
    wkv6 over [0:T) — for ANY split point and chunk size, i.e. the chunk
    grid and the zero-padding are invisible to the recurrence semantics."""
    T, dk, dv = 23, 6, 6
    r, k, v, logw, u, s0 = _inputs(T, dk, dv, seed)
    out_full, s_full = wkv6_lib.wkv6(r, k, v, logw, u, s0, chunk=chunk)
    cut = lambda a, lo, hi: a[:, lo:hi]
    out_a, s_mid = wkv6_lib.wkv6(cut(r, 0, split), cut(k, 0, split),
                                 cut(v, 0, split), cut(logw, 0, split),
                                 u, s0, chunk=chunk)
    out_b, s_end = wkv6_lib.wkv6(cut(r, split, T), cut(k, split, T),
                                 cut(v, split, T), cut(logw, split, T),
                                 u, s_mid, chunk=chunk)
    np.testing.assert_allclose(np.concatenate([out_a, out_b], axis=1),
                               np.asarray(out_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# BH tiling: non-dividing batch-head tails against the shared state scratch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bh_tile", [2, 3, 4])
@pytest.mark.parametrize("chunk", [1, 8, 23])
def test_bh_tile_forward_bitwise_nondividing_tail(bh_tile, chunk):
    """Widening the grid's BH axis must not change a single bit of the
    forward: the per-row unroll inside a tile runs the exact chunk math of
    the bh_tile=1 sweep, and the zero-padded tail rows (BH=5 divides none
    of these tiles) write only their own rows of the shared f32 state
    scratch.  chunk spans C=1 / C | T-ish / C=T over a NON-dividing T=23,
    so the time padding rides along too."""
    T, dk, dv, BH = 23, 6, 6, 5
    args = _inputs(T, dk, dv, seed=7, BH=BH)
    out1, s1 = wkv6_lib.wkv6(*args, chunk=chunk, bh_tile=1)
    outn, sn = wkv6_lib.wkv6(*args, chunk=chunk, bh_tile=bh_tile)
    np.testing.assert_array_equal(np.asarray(outn), np.asarray(out1))
    np.testing.assert_array_equal(np.asarray(sn), np.asarray(s1))


def test_bh_tile_rows_match_independent_single_rows():
    """Each batch-head row of a tiled run equals its OWN single-row run —
    the direct statement that the shared (bh_tile, dk, dv) state scratch
    never leaks across rows, tail rows of a non-dividing BH included."""
    T, dk, dv, BH = 16, 4, 4, 3
    r, k, v, logw, u, s0 = _inputs(T, dk, dv, seed=11, BH=BH)
    out, s_out = wkv6_lib.wkv6(r, k, v, logw, u, s0, chunk=8, bh_tile=2)
    for i in range(BH):
        oi, si = wkv6_lib.wkv6(r[i:i + 1], k[i:i + 1], v[i:i + 1],
                               logw[i:i + 1], u[i:i + 1], s0[i:i + 1],
                               chunk=8, bh_tile=1)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(oi[0]))
        np.testing.assert_array_equal(np.asarray(s_out[i]),
                                      np.asarray(si[0]))


def test_bh_tile_grads_agree_nondividing_tail():
    """The reverse sweep shares the row layout (per-row vjp over the same
    chunk math, ds/du scratch rows owned per batch-head), so gradients
    agree across bh tiles too — to float rounding, not bitwise: different
    grids are different XLA programs, so fusion may reassociate."""
    T, dk, dv, BH = 23, 4, 4, 5
    args = _inputs(T, dk, dv, seed=13, BH=BH)

    def loss(bh_tile, *a):
        out, s = wkv6_lib.wkv6(*a, chunk=8, bh_tile=bh_tile)
        return jnp.sum(jnp.tanh(out.astype(jnp.float32))) + jnp.sum(s * s)

    g1 = jax.grad(lambda *a: loss(1, *a), argnums=tuple(range(6)))(*args)
    for bh_tile in (2, 5):
        gn = jax.grad(lambda *a: loss(bh_tile, *a),
                      argnums=tuple(range(6)))(*args)
        for a, b in zip(gn, g1):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
