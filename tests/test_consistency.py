"""Integration invariant: prefill + step-by-step decode reproduces the
full-sequence forward logits for EVERY architecture family (the recurrent
state handling, KV caches, ring buffers and MoE no-drop dispatch all have to
be right simultaneously for this to hold)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.partitioning import split

# multi-second integration sweeps: excluded from the quick loop (-m "not slow")
pytestmark = pytest.mark.slow

SHAPE = ShapeConfig("smoke", 33, 2, "train")
PREFIX, EXTRA = 16, 2
TOL = dict(rtol=3e-4, atol=3e-4)


def _setup(name, **cfg_overrides):
    cfg = ARCHS[name].reduced()
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    m = registry.build(cfg)
    params, _ = split(m.init(jax.random.PRNGKey(0)))
    batch = registry.make_batch(cfg, SHAPE, jax.random.PRNGKey(1))
    return cfg, m, params, batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_equals_forward(name):
    cfg, m, params, batch = _setup(name)
    toks = batch["tokens"]
    cache, _ = split(m.init_cache(2, 64))
    if cfg.n_codebooks:
        pre = {"tokens": toks[:, :, :PREFIX]}
        full = {"tokens": toks[:, :, :PREFIX + EXTRA]}
    elif cfg.n_vis_tokens:
        pre = {"tokens": toks[:, :PREFIX], "vis_embeds": batch["vis_embeds"]}
        full = {"tokens": toks[:, :PREFIX + EXTRA],
                "vis_embeds": batch["vis_embeds"]}
    else:
        pre = {"tokens": toks[:, :PREFIX]}
        full = {"tokens": toks[:, :PREFIX + EXTRA]}
    fl, _ = m.forward(params, full, inference=True)
    pl, cache = m.prefill(params, cache, pre)
    off = cfg.n_vis_tokens
    if cfg.n_codebooks:
        np.testing.assert_allclose(pl[:, :, 0], fl[:, :, PREFIX - 1], **TOL)
        for t in range(EXTRA):
            d, cache = m.decode_step(params, cache,
                                     {"tokens": toks[:, :, PREFIX + t]})
            np.testing.assert_allclose(d, fl[:, :, PREFIX + t], **TOL)
    else:
        np.testing.assert_allclose(pl[:, 0], fl[:, off + PREFIX - 1], **TOL)
        for t in range(EXTRA):
            d, cache = m.decode_step(params, cache,
                                     {"tokens": toks[:, PREFIX + t]})
            np.testing.assert_allclose(d, fl[:, off + PREFIX + t], **TOL)


def test_sliding_window_ring_cache_matches_windowed_forward():
    """A ring cache of width W must reproduce the windowed full forward."""
    cfg, m, params, batch = _setup("yi-9b", sliding_window=8)
    toks = batch["tokens"][:, :24]
    cache, _ = split(m.init_cache(2, 64))     # ring: min(64, W=8) slots
    fl, _ = m.forward(params, {"tokens": toks}, inference=True)
    pl, cache = m.prefill(params, cache, {"tokens": toks[:, :20]})
    np.testing.assert_allclose(pl[:, 0], fl[:, 19], **TOL)
    for t in range(20, 24):
        d, cache = m.decode_step(params, cache, {"tokens": toks[:, t]})
        np.testing.assert_allclose(d, fl[:, t], **TOL)


def test_window_equals_full_when_window_covers_seq():
    cfg_w, m_w, params, batch = _setup("yi-9b", sliding_window=64)
    cfg_f, m_f, _, _ = _setup("yi-9b")
    toks = batch["tokens"][:, :24]
    a, _ = m_w.forward(params, {"tokens": toks}, inference=True)
    b, _ = m_f.forward(params, {"tokens": toks}, inference=True)
    np.testing.assert_allclose(a, b, **TOL)


def test_rwkv_chunk_size_is_execution_detail():
    """MobiRNN invariant at model level: the chunk (work-unit) size of the
    rwkv scan must not change the logits."""
    outs = []
    for chunk in (1, 4, 16):
        cfg = ARCHS["rwkv6-3b"].reduced()
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=chunk))
        m = registry.build(cfg)
        params, _ = split(m.init(jax.random.PRNGKey(0)))
        batch = registry.make_batch(cfg, SHAPE, jax.random.PRNGKey(1))
        logits, _ = m.forward(params, {"tokens": batch["tokens"][:, :32]})
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], **TOL)
    np.testing.assert_allclose(outs[0], outs[2], **TOL)
