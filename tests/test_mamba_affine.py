"""Iteration-E feasibility: the Mamba selective scan is affine in its state,
so sequence shards compose exactly like the distributed wkv pipeline.

Property checked: running the scan over [seg1 ++ seg2] from state h0 equals
applying seg2's scan to seg1's final state, AND equals the composed affine
summary applied to h0 — the identity the cross-chip prefix exchange relies
on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import mamba
from repro.partitioning import split


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("jamba-1.5-large-398b").reduced()
    p, _ = split(mamba.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32))
    B, S = 2, 16
    di, ds = mamba.d_inner(cfg), cfg.ssm.d_state
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    xc = jax.random.normal(ks[0], (B, S, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    b_mat = jax.random.normal(ks[2], (B, S, ds))
    c_mat = jax.random.normal(ks[3], (B, S, ds))
    h0 = jax.random.normal(ks[4], (B, di, ds)) * 0.3
    return cfg, p, xc, dt, b_mat, c_mat, h0


def test_segment_chaining_equals_full_scan(setup):
    cfg, p, xc, dt, b, c, h0 = setup
    y_full, h_full = mamba._scan(p, xc, dt, b, c, h0)
    y1, h_mid = mamba._scan(p, xc[:, :8], dt[:, :8], b[:, :8], c[:, :8], h0)
    y2, h_end = mamba._scan(p, xc[:, 8:], dt[:, 8:], b[:, 8:], c[:, 8:],
                            h_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_end, h_full, rtol=1e-5, atol=1e-5)


def test_affine_summary_identity(setup):
    """h_out(seg, h0) == D_seg ⊙ h0 + A_seg — the distributable form."""
    cfg, p, xc, dt, b, c, h0 = setup
    zero = jnp.zeros_like(h0)
    _, a_seg = mamba._scan(p, xc, dt, b, c, zero)       # scan-from-zero
    d_seg = mamba.scan_summary(p, dt, b)
    _, h_direct = mamba._scan(p, xc, dt, b, c, h0)
    np.testing.assert_allclose(d_seg * h0 + a_seg, h_direct,
                               rtol=1e-5, atol=1e-5)


def test_affine_composition(setup):
    """Composing two half-segment summaries == the full-segment summary."""
    cfg, p, xc, dt, b, c, h0 = setup
    zero = jnp.zeros_like(h0)
    halves = []
    for sl in (slice(0, 8), slice(8, 16)):
        _, a = mamba._scan(p, xc[:, sl], dt[:, sl], b[:, sl], c[:, sl],
                           zero)
        d = mamba.scan_summary(p, dt[:, sl], b[:, sl])
        halves.append((d, a))
    d12, a12 = mamba.compose_affine(*halves[0], *halves[1])
    _, a_full = mamba._scan(p, xc, dt, b, c, zero)
    d_full = mamba.scan_summary(p, dt, b)
    np.testing.assert_allclose(d12, d_full, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(a12, a_full, rtol=1e-5, atol=1e-5)
