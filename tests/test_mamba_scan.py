"""Tests on the fused Mamba selective scan (kernels/mamba_scan.py) — the
mamba family's Pallas fast path: oracle equivalence (against both the
kernel's lax.scan reference and the MODEL's own recurrence in
models/mamba._scan), O(1)-in-T dispatch counts through the custom VJP,
identity zero-padding on both axes, and the (block_b, chunk) budget table
on the shared core/tiling substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.kernels import mamba_scan as ms_lib

B, T, DI, DS = 3, 23, 8, 4


def _inputs(batch=B, seq=T, di=DI, ds=DS, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (batch, seq, di), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (batch, seq, di)))
    b = jax.random.normal(ks[2], (batch, seq, ds))
    c = jax.random.normal(ks[3], (batch, seq, ds))
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)))
    h0 = jax.random.normal(ks[5], (batch, di, ds)) * 0.3
    return x, dt, b, c, a, h0


def _loss(*args, **kw):
    y, h = ms_lib.mamba_scan(*args, **kw)
    return jnp.sum(jnp.tanh(y.astype(jnp.float32))) + 0.5 * jnp.sum(h * h)


def test_ref_matches_model_scan():
    """mamba_scan_ref IS the model recurrence: same ys and final state as
    models/mamba._scan given the same a = -exp(a_log)."""
    from repro.models import mamba as mamba_lib

    x, dt, b, c, a, h0 = _inputs()
    ys_ref, h_ref = ms_lib.mamba_scan_ref(x, dt, b, c, a, h0)
    # d_skip=0 strips the model's residual skip, leaving the raw scan
    ys_mod, h_mod = mamba_lib._scan(
        {"a_log": jnp.log(-a), "d_skip": jnp.zeros((DI,))}, x, dt, b, c, h0)
    np.testing.assert_allclose(np.asarray(ys_ref), np.asarray(ys_mod),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_ref), np.asarray(h_mod),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_b", [1, 2, 3, None])
@pytest.mark.parametrize("chunk", [1, 8, 16, 23])
def test_forward_matches_oracle(chunk, block_b):
    """Fused kernel == lax.scan oracle across the (chunk, block_b)
    surface: C=1 / C non-dividing T / C=T, batch tiles dividing and not
    (B=3), the full identity-zero-pad exercise."""
    args = _inputs()
    y_ref, h_ref = ms_lib.mamba_scan_ref(*args)
    y, h = ms_lib.mamba_scan(*args, chunk=chunk, block_b=block_b)
    assert y.dtype == args[0].dtype and h.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-5, atol=2e-5)


def test_grads_match_oracle():
    args = _inputs(seed=3)

    def ref_loss(*a):
        y, h = ms_lib.mamba_scan_ref(*a)
        return (jnp.sum(jnp.tanh(y.astype(jnp.float32)))
                + 0.5 * jnp.sum(h * h))

    g_ref = jax.grad(ref_loss, argnums=tuple(range(6)))(*args)
    g = jax.grad(lambda *a: _loss(*a, chunk=8, block_b=2),
                 argnums=tuple(range(6)))(*args)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


def test_oracle_bwd_fallback_matches():
    """bwd=ORACLE_BWD replays the scan reference for the backward — same
    gradients as the fused reverse sweep within float rounding."""
    args = _inputs(seed=5)
    g_fused = jax.grad(lambda *a: _loss(*a, chunk=8, block_b=2),
                       argnums=tuple(range(6)))(*args)
    g_oracle = jax.grad(
        lambda *a: _loss(*a, chunk=8, block_b=2, bwd=ms_lib.ORACLE_BWD),
        argnums=tuple(range(6)))(*args)
    for got, want in zip(g_fused, g_oracle):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("seq", [16, 61, 256])
def test_dispatch_counts_O1_in_T(seq):
    """1 forward dispatch and 2 train dispatches at ANY T — the registered
    PlanSpec contract; the oracle backward drops to 1 train dispatch
    (scan replay, no reverse-sweep kernel)."""
    args = _inputs(batch=2, seq=seq)
    jx = jax.make_jaxpr(
        lambda *a: ms_lib.mamba_scan(*a, chunk=16, block_b=2))(*args)
    assert analysis.count_kernel_dispatches(jx) == 1
    n_train = analysis.count_train_dispatches(
        lambda *a: _loss(*a, chunk=16, block_b=2), *args)
    assert n_train == 2
    n_oracle = analysis.count_train_dispatches(
        lambda *a: _loss(*a, chunk=16, block_b=2, bwd=ms_lib.ORACLE_BWD),
        *args)
    assert n_oracle == 1


def test_grid_steps_O_T_over_C():
    """Grid is (ceil(B/bm), ceil(T/C)): the sequential work a dispatch
    count cannot see, the fig2 grid-step rows' contract."""
    args = _inputs(batch=3, seq=61)
    jx = jax.make_jaxpr(
        lambda *a: ms_lib.mamba_scan(*a, chunk=8, block_b=2))(*args)
    assert analysis.count_pallas_grid_steps(jx) == 2 * 8


def test_choose_blocks_coarseness_order():
    # whole-T residency at the full batch tile when the budget allows
    assert ms_lib.choose_blocks(4, 64, 16, 8) == ms_lib.MambaBlocks(4, 64)
    # under pressure the time axis streams before the batch tile halves
    ws_full = ms_lib.working_set_bytes(64, 16, 8, 4, 64)
    tight = ms_lib.choose_blocks(4, 64, 16, 8, vmem_budget=ws_full - 1)
    assert tight is not None and tight.block_b == 4 and tight.chunk < 64
    # bwd mode is stricter than fwd at the same budget
    ws_bwd = ms_lib.working_set_bytes(64, 16, 8, 4, 64, mode="bwd")
    assert ws_bwd > ws_full
    # hopeless budgets report non-viability instead of lying
    assert ms_lib.choose_blocks(4, 4096, 4096, 64, vmem_budget=4096) is None
