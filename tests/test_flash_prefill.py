"""flash_prefill Pallas kernel: shape/dtype/window sweeps vs naive oracle,
agreement with the model's jnp blockwise attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,S,Hq,Hkv,dh,qb,kb,w", [
    (2, 64, 4, 2, 32, 16, 16, 0),
    (1, 128, 8, 8, 16, 32, 64, 0),
    (2, 96, 4, 1, 32, 32, 32, 24),
    (1, 60, 2, 2, 16, 16, 16, 0),      # partial blocks
    (1, 60, 2, 2, 16, 16, 16, 20),     # partial blocks + window
])
def test_flash_prefill_sweep(B, S, Hq, Hkv, dh, qb, kb, w):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq + w), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, dh))
    o1 = ops.flash_prefill(q, k, v, window=w, q_block=qb, k_block=kb)
    o2 = ref.prefill_attn(q, k, v, window=w)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_dtypes(dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    o1 = ops.flash_prefill(q, k, v, q_block=32, k_block=32)
    o2 = ref.prefill_attn(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


def test_flash_prefill_matches_model_attention():
    from repro.models.attention import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 96, 4, 16))
    k = jax.random.normal(ks[1], (2, 96, 2, 16))
    v = jax.random.normal(ks[2], (2, 96, 2, 16))
    o_kernel = ops.flash_prefill(q, k, v, q_block=32, k_block=32)
    o_model = flash_attention(q, k, v, q_block=32, kv_block=32)
    np.testing.assert_allclose(o_kernel, o_model, rtol=2e-4, atol=2e-4)


def test_block_size_never_changes_results():
    """MobiRNN invariant at kernel level."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    outs = [ops.flash_prefill(q, k, v, q_block=qb, k_block=kb)
            for qb, kb in [(16, 16), (64, 64), (32, 16), (16, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-4, atol=2e-4)
