"""MoE dispatch unit tests + routing conservation properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import moe as moe_lib
from repro.partitioning import split


def _cfg(n_experts=4, top_k=2, cf=1.25):
    cfg = get_arch("olmoe-1b-7b").reduced()
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=n_experts,
                                     top_k=top_k, capacity_factor=cf))


def _params(cfg):
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    return split(p)[0]


@pytest.mark.slow
def test_no_drop_matches_manual_dense_computation():
    """With no_drop, the capacity path must equal the direct dense formula
    sum_k w_k * expert_{e_k}(x)."""
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, cfg.d_model))
    out, aux = moe_lib.apply_moe(p, x, cfg, no_drop=True)

    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    expected = jnp.zeros_like(x)
    for t in range(x.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            h = (jax.nn.silu(x[t] @ p["wg"][e]) * (x[t] @ p["wu"][e])
                 ) @ p["wd"][e]
            acc = acc + top_p[t, j] * h
        expected = expected.at[t].set(acc)
    np.testing.assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


def test_drop_fraction_zero_when_capacity_ample():
    cfg = _cfg(cf=8.0)   # cf >= E/k guarantees zero drops
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, cfg.d_model))
    _, aux = moe_lib.apply_moe(p, x, cfg)
    assert float(aux["moe_drop_frac"]) == 0.0


def test_drop_fraction_positive_when_capacity_tight():
    cfg = _cfg(cf=0.25)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, cfg.d_model))
    _, aux = moe_lib.apply_moe(p, x, cfg)
    assert float(aux["moe_drop_frac"]) > 0.0


def test_capacity_is_work_unit_coarseness():
    cfg = _cfg(cf=1.0)
    assert moe_lib.capacity(64, cfg) == 64 * 2 // 4
    assert moe_lib.capacity(1, cfg) == cfg.moe.top_k   # floor


def test_load_balance_loss_bounds():
    """Perfectly uniform router -> load_balance == 1 (switch normalisation);
    collapsed router -> E."""
    cfg = _cfg()
    E = cfg.moe.n_experts
    p = _params(cfg)
    # uniform: zero router weights
    p2 = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(4), (128, cfg.d_model))
    _, aux = moe_lib.apply_moe(p2, x, cfg)
    # with zero logits top-1 is argmax ties -> index 0; me uniform
    assert 0.9 < float(aux["moe_load_balance"]) <= E + 1e-3
    # collapsed: huge bias to expert 0
    p3 = dict(p, router=p["router"] * 0 + jnp.eye(cfg.d_model, E) * 50)
    _, aux3 = moe_lib.apply_moe(p3, x, cfg)
    assert float(aux3["moe_load_balance"]) >= float(aux["moe_load_balance"])


def test_gradients_flow_to_all_expert_weights_no_drop():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (64, cfg.d_model))

    def loss(p):
        out, _ = moe_lib.apply_moe(p, x, cfg, no_drop=True)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(p)
    # router always gets gradient; with 64 tokens over 4 experts top-2 all
    # experts are essentially surely hit
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    per_expert = jnp.sum(jnp.abs(g["wd"]), axis=(1, 2))
    assert bool(jnp.all(per_expert > 0))
