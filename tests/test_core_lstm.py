"""Paper-core tests: fused/fine cell equivalence, wavefront == sequential,
Pallas cell kernel, preallocation accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MOBIRNN_LSTM
from repro.core import cell as cell_lib
from repro.core import lstm, wavefront
from repro.partitioning import split


@pytest.fixture(scope="module")
def setup():
    cfg = MOBIRNN_LSTM
    key = jax.random.PRNGKey(0)
    params = lstm.init_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.seq_len,
                                                  cfg.input_dim))
    return cfg, params, x


@pytest.mark.slow
def test_fused_equals_fine(setup):
    """MobiRNN's coarse factorization must be numerically identical to the
    desktop-CUDA per-column plan (paper §3: same math, different units)."""
    cfg, params, x = setup
    p, _ = split(params)
    c = jnp.zeros((4, cfg.hidden))
    h = jnp.zeros((4, cfg.hidden))
    c1, h1 = cell_lib.lstm_cell_fused(p["layers"][0], x[:, 0], c, h)
    for unit_cols in (1, 4, 8):
        c2, h2 = cell_lib.lstm_cell_fine(p["layers"][0], x[:, 0], c, h,
                                         unit_cols=unit_cols)
        np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-5)


def test_wavefront_equals_sequential(setup):
    """Fig 1 diagonal schedule is an execution-order change only."""
    cfg, params, x = setup
    a = lstm.forward_sequential(params, x, cfg)
    b = lstm.forward_wavefront(params, x, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


def test_kernel_plan_equals_sequential(setup):
    cfg, params, x = setup
    a = lstm.forward_sequential(params, x[:, :8], cfg)
    b = lstm.forward_fused_kernel(params, x[:, :8], cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("layers,seq,expected", [(3, 4, 3), (2, 128, 2),
                                                 (5, 3, 3)])
def test_wavefront_width(layers, seq, expected):
    assert wavefront.wavefront_width(layers, seq) == expected
    assert wavefront.live_buffers(layers, seq) == 2 * expected


def test_paper_buffer_count_figure1():
    """Paper §3.2: for the 3-layer x 4-step example, 6 buffers instead of
    24 — preallocation bound is 2 x wavefront width."""
    assert wavefront.live_buffers(3, 4) == 6
    assert 2 * 3 * 4 == 24  # the naive per-cell allocation it replaces


@pytest.mark.slow
def test_grad_flows_through_all_plans(setup):
    cfg, params, x = setup
    labels = jnp.array([0, 1, 2, 3])
    for fwd in (lstm.forward_sequential, lstm.forward_wavefront):
        g = jax.grad(lstm.loss_fn)(params, x, labels, cfg, forward=fwd)
        leaves = jax.tree.leaves(g)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
        total = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
        assert total > 0.0
