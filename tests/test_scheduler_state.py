"""Load-aware scheduler (paper Fig 7) and preallocated state pools (§3.2)."""
import jax.numpy as jnp
import pytest

from repro.core.scheduler import (Plan, ProcLoadSensor, Scheduler,
                                  SyntheticLoadSensor)
from repro.core.state import StatePool
import jax


def _sched(accel_base=0.03, cpu_base=0.1):
    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("accel", lambda: None, base_latency_s=accel_base,
                    shared=True, sensitivity=1.0))
    s.register(Plan("cpu", lambda: None, base_latency_s=cpu_base,
                    shared=False))
    return s


def test_low_load_prefers_accelerator():
    s = _sched()
    assert s.choose(load=0.1).plan == "accel"
    assert s.choose(load=0.4).plan == "accel"


def test_high_load_crosses_over_to_cpu():
    """Paper Fig 7: under high accelerator load the CPU path wins."""
    s = _sched()
    assert s.choose(load=0.9).plan == "cpu"


def test_crossover_point_matches_contention_model():
    # accel wins iff base/(1-load) < cpu_base  =>  load < 1 - accel/cpu
    s = _sched(accel_base=0.03, cpu_base=0.1)
    crossover = 1 - 0.03 / 0.1
    assert s.choose(load=crossover - 0.05).plan == "accel"
    assert s.choose(load=crossover + 0.05).plan == "cpu"


def test_observation_updates_base_latency():
    s = _sched()
    p = s.plans["accel"]
    for _ in range(50):
        p.observe(0.2, load=0.0)      # accel got slow
    assert s.choose(load=0.0).plan == "cpu"


def test_proc_sensor_in_range():
    v = ProcLoadSensor().load()
    assert 0.0 <= v <= 1.0


# ---------------------------------------------------------------------------
# Viability filtering (ROADMAP: schedule from the VMEM model, not just EMA)
# ---------------------------------------------------------------------------
def test_viability_filters_choose():
    s = _sched()                      # accel would win at low load...
    s.viable = lambda name: name != "accel"
    assert s.choose(load=0.0).plan == "cpu"   # ...but it is not viable


def test_viability_never_calibrates_nonviable():
    calls = []
    s = Scheduler(SyntheticLoadSensor(0.0),
                  viable=lambda name: name == "cpu")
    s.register(Plan("accel", lambda: calls.append("accel"), shared=True))
    s.register(Plan("cpu", lambda: calls.append("cpu"), shared=False))
    s.calibrate(repeats=1)
    # one untimed warmup + one timed repeat, the non-viable plan never runs
    assert calls == ["cpu", "cpu"]
    assert s.plans["accel"].base_latency_s == float("inf")


def test_calibrate_warmup_excludes_compile_cost():
    """Regression: calibrate used to time the FIRST call, so jit compile
    cost landed in base_latency_s and poisoned every choose() afterwards.
    A fn that is slow exactly once (compile) must calibrate to its
    steady-state latency."""
    import time as _time

    calls = []

    def fn():
        calls.append(1)
        if len(calls) == 1:
            _time.sleep(0.05)        # "compilation" on first invocation

    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("p", fn))
    s.calibrate(repeats=1)
    assert len(calls) == 2           # warmup + one timed repeat
    # the timed repeat must not see the 50ms first-call cost
    assert s.plans["p"].base_latency_s < 0.025


def test_calibrate_seeds_from_profile_without_running():
    """A persisted device profile short-circuits measurement: profiled
    plans take their base latency from the profile and their fn is never
    invoked; unprofiled plans still get the measured path."""
    ran = []
    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("profiled", lambda: ran.append("profiled")))
    s.register(Plan("measured", lambda: ran.append("measured")))
    s.calibrate(repeats=1, profile={"profiled": 0.007})
    assert s.plans["profiled"].base_latency_s == 0.007
    assert "profiled" not in ran
    assert ran == ["measured", "measured"]      # warmup + timed
    assert s.plans["measured"].base_latency_s < float("inf")


def test_viability_rejecting_everything_raises():
    s = _sched()
    with pytest.raises(ValueError, match="no viable plan"):
        s.choose(load=0.0, viable=lambda name: False)


def test_plan_viability_from_vmem_model():
    """kernels/lstm_seq.choose_batch_block wires into Scheduler(viable=...):
    past the VMEM budget the sequence-resident plan is filtered out."""
    from repro.configs import MOBIRNN_LSTM
    from repro.core import lstm

    cfg = MOBIRNN_LSTM
    fits = lstm.plan_viability(cfg, 8, cfg.seq_len)
    assert fits("fused_seq") and fits("fused_cell") and fits("sequential")
    tiny = lstm.plan_viability(cfg, 8, cfg.seq_len, vmem_budget=1024)
    assert not tiny("fused_seq")
    assert tiny("fused_cell") and tiny("sequential")  # fallbacks stay

    s = Scheduler(SyntheticLoadSensor(0.0), viable=tiny)
    s.register(Plan("fused_seq", lambda: None, base_latency_s=0.001,
                    shared=True))
    s.register(Plan("fused_cell", lambda: None, base_latency_s=0.01,
                    shared=True))
    assert s.choose(load=0.0).plan == "fused_cell"


def test_plan_viability_train_mode_is_stricter():
    """Under jax.grad the fused-seq working set grows ~3x (trajectory
    residuals + gradient accumulators), so there is a budget window where
    the plan is viable for inference but NOT for training — a train-time
    scheduler must pass train=True or it will pick a plan whose backward
    silently drops to the oracle replay.  With time streaming the window
    is the gap between the two modes' (bm=1, tc=1) FLOORS — the f32 dw/db
    accumulators and gradient outputs that no amount of chunking can
    shrink — narrower than the old whole-T-resident gap, but still there."""
    from repro.configs import MOBIRNN_LSTM
    from repro.core import lstm
    from repro.kernels import lstm_seq as seq_lib

    cfg = MOBIRNN_LSTM
    p_width = max(cfg.input_dim, cfg.hidden)
    floor = dict(block_b=1, mode="bwd", time_chunk=1)
    bwd_floor = seq_lib.working_set_bytes(
        cfg.seq_len, cfg.n_layers, p_width, cfg.hidden, **floor)
    budget = bwd_floor - 1
    infer = lstm.plan_viability(cfg, 8, cfg.seq_len, vmem_budget=budget)
    train = lstm.plan_viability(cfg, 8, cfg.seq_len, vmem_budget=budget,
                                train=True)
    assert infer("fused_seq")
    assert not train("fused_seq")
    assert train("fused_cell") and train("sequential")  # fallbacks stay
    # with a real budget both modes admit the plan
    assert lstm.plan_viability(cfg, 8, cfg.seq_len, train=True)("fused_seq")


def test_plan_viability_long_T_streams_instead_of_filtering():
    """The (block_b, time_chunk) decision table makes the Fig 7 viability
    surface T-independent: long sequences stream the time axis through
    double-buffered chunks instead of disqualifying fused_seq — only a
    weight stack (plus gradient accumulators under train=True) that blows
    the budget at (bm=1, tc=1) still filters it out."""
    from repro.configs import MOBIRNN_LSTM
    from repro.core import lstm
    from repro.core.factorization import MOBILE_VMEM_BUDGET

    cfg = MOBIRNN_LSTM
    budget = MOBILE_VMEM_BUDGET   # whole-T bwd falls off it by T=512
    for T in (128, 512, 2048, 8192):
        for train in (False, True):
            ok = lstm.plan_viability(cfg, 2, T, vmem_budget=budget,
                                     train=train)
            assert ok("fused_seq"), (T, train)
    # the weight-stack floor is the only remaining filter
    floor = lstm.plan_viability(cfg, 2, 128, vmem_budget=16 << 10)
    assert not floor("fused_seq")
    assert floor("fused_cell") and floor("sequential")


def test_plan_viability_quantized_widens_both_windows():
    """ISSUE 5: the int8 plan's viability surface strictly contains the f32
    plan's.  The inference-viable-vs-train-viable window shifts DOWN with
    1-byte weights: budgets exist where (a) f32 is not even
    inference-viable but q8 is, and (b) f32 training falls back while q8
    training stays fused — because both (bm=1, tc=1) floors drop by the
    quartered weight stack (fwd) / stack + f32-outs delta (bwd)."""
    from repro.configs import MOBIRNN_LSTM
    from repro.core import lstm
    from repro.kernels import lstm_seq as seq_lib

    cfg = MOBIRNN_LSTM
    p_width = max(cfg.input_dim, cfg.hidden)

    def floor(mode, quantized):
        return seq_lib.working_set_bytes(
            cfg.seq_len, cfg.n_layers, p_width, cfg.hidden, 1, mode=mode,
            time_chunk=1, quantized=quantized)

    # the q8 floors sit strictly below the f32 floors in both modes
    assert floor("fwd", True) < floor("fwd", False)
    assert floor("bwd", True) < floor("bwd", False)

    # (a) inference window: below the f32 fwd floor, above the q8 one
    budget = floor("fwd", False) - 1
    infer = lstm.plan_viability(cfg, 8, cfg.seq_len, vmem_budget=budget)
    assert not infer("fused_seq")
    assert infer("fused_seq_q8")
    assert infer("fused_cell") and infer("sequential")

    # (b) training window: below the f32 bwd floor, above the q8 one —
    # the old inference-viable-but-not-train-viable gap now ALSO has a
    # quantized escape hatch before the fused_cell fallback
    budget = floor("bwd", False) - 1
    assert budget > floor("bwd", True)
    train = lstm.plan_viability(cfg, 8, cfg.seq_len, vmem_budget=budget,
                                train=True)
    assert not train("fused_seq")
    assert train("fused_seq_q8")

    # (c) below the q8 bwd floor both fused-seq plans are out; the q8 fwd
    # can still be inference-viable there (its window is wider than its
    # train window, exactly like f32)
    budget = floor("bwd", True) - 1
    train_tiny = lstm.plan_viability(cfg, 8, cfg.seq_len,
                                     vmem_budget=budget, train=True)
    assert not train_tiny("fused_seq_q8")
    assert not train_tiny("fused_seq")
    assert train_tiny("fused_cell") and train_tiny("sequential")
    if budget >= floor("fwd", True):
        assert lstm.plan_viability(cfg, 8, cfg.seq_len,
                                   vmem_budget=budget)("fused_seq_q8")

    # at a real budget every plan is viable in both modes
    full = lstm.plan_viability(cfg, 8, cfg.seq_len, train=True)
    assert full("fused_seq") and full("fused_seq_q8")


# ---------------------------------------------------------------------------
# Two-family registry viability (ISSUE 6): ONE scheduler serving lstm AND
# rwkv6 plans through core/plans.scheduler_viability — a budget-non-viable
# rwkv plan is never calibrated and never chosen, while the other family's
# plans and the CPU fallbacks are untouched.
# ---------------------------------------------------------------------------
def _two_family_viable(rwkv_budget=None):
    from repro.configs import MOBIRNN_LSTM
    from repro.core import lstm, plans

    cfg = MOBIRNN_LSTM
    return plans.scheduler_viability({
        "accel_seq": ("fused_seq",
                      lstm.plan_viability(cfg, 8, cfg.seq_len)),
        "accel_wkv": ("chunked_scan",
                      plans.rwkv_viability(128, 64, 64,
                                           vmem_budget=rwkv_budget)),
    })


def test_two_family_nonviable_rwkv_never_calibrated_or_chosen():
    """rwkv's choose_chunk finds nothing at a 2 KiB budget (the per-head
    state blocks alone blow it), so the bound scheduler name is filtered
    everywhere; the lstm family's fast path and the unbound CPU plans are
    unaffected."""
    calls = []
    viable = _two_family_viable(rwkv_budget=2048)
    s = Scheduler(SyntheticLoadSensor(0.0), viable=viable)
    s.register(Plan("accel_wkv", lambda: calls.append("accel_wkv"),
                    base_latency_s=0.001, shared=True))   # would always win
    s.register(Plan("accel_seq", lambda: calls.append("accel_seq"),
                    base_latency_s=0.01, shared=True))
    s.register(Plan("cpu", lambda: calls.append("cpu"), base_latency_s=0.1,
                    shared=False))
    s.calibrate(repeats=1)
    assert "accel_wkv" not in calls
    # calibrate never ran it: the registered base is untouched — and even
    # with the winning latency on the books, choose filters it out
    assert s.plans["accel_wkv"].base_latency_s == 0.001
    for load in (0.0, 0.5, 0.95):
        assert s.choose(load=load).plan != "accel_wkv"
    # the lstm fast path and the CPU fallback were both calibrated and
    # remain choosable (which wins is calibration noise between no-op fns)
    assert "accel_seq" in calls and "cpu" in calls
    assert viable("accel_seq") and viable("cpu")


def test_two_family_real_budget_admits_both_fast_paths():
    viable = _two_family_viable(rwkv_budget=None)          # default budget
    assert viable("accel_seq") and viable("accel_wkv") and viable("cpu")
    s = Scheduler(SyntheticLoadSensor(0.0), viable=viable)
    s.register(Plan("accel_wkv", lambda: None, base_latency_s=0.001,
                    shared=True))
    s.register(Plan("cpu", lambda: None, base_latency_s=0.1, shared=False))
    assert s.choose(load=0.0).plan == "accel_wkv"


def test_rwkv_viability_train_mode_is_stricter():
    """The reverse-sweep backward holds ~3x the forward working set, so a
    budget window exists where the Pallas wkv plan is inference-viable but
    not train-viable — mirroring the lstm family's train=True contract."""
    from repro.core import plans
    from repro.kernels import wkv6 as wkv6_lib

    S, dk, dv = 128, 64, 64
    fwd_need = wkv6_lib.working_set_bytes(S, dk, dv, 1, mode="fwd")
    bwd_need = wkv6_lib.working_set_bytes(S, dk, dv, 1, mode="bwd")
    assert bwd_need > fwd_need
    budget = bwd_need - 1
    infer = plans.rwkv_viability(S, dk, dv, vmem_budget=budget)
    train = plans.rwkv_viability(S, dk, dv, vmem_budget=budget, train=True)
    assert infer("chunked_scan")
    assert not train("chunked_scan")
    assert train("stepwise") and train("chunked_xla")      # fallbacks stay


def test_rwkv_choose_chunk_halves_under_pressure():
    """The (C,) decision mirrors SeqBlocks coarseness order: full target
    chunk at a real budget, halved chunks as the budget shrinks (the
    (C, C, dk) intra-chunk tensor is the dominant term), None only when
    even C=1 does not fit."""
    from repro.kernels import wkv6 as wkv6_lib

    S, dk, dv = 128, 64, 64
    full = wkv6_lib.choose_chunk(S, dk, dv, target=32)
    assert full == wkv6_lib.WkvBlocks(32)
    seen = {full.chunk}
    budget = wkv6_lib.working_set_bytes(S, dk, dv, 32) - 1
    while True:
        blocks = wkv6_lib.choose_chunk(S, dk, dv, target=32,
                                       vmem_budget=budget)
        if blocks is None:
            break
        assert blocks.chunk < 32 and 32 % blocks.chunk == 0
        assert wkv6_lib.working_set_bytes(
            S, dk, dv, blocks.chunk) <= budget
        seen.add(blocks.chunk)
        budget = wkv6_lib.working_set_bytes(S, dk, dv, blocks.chunk) - 1
    assert len(seen) >= 3                   # the search actually halves
    assert wkv6_lib.choose_chunk(S, dk, dv, vmem_budget=64) is None


def test_tile_plan_protocol_unifies_family_blocks():
    """ISSUE 10 satellite: SeqBlocks / WkvBlocks / MambaBlocks all satisfy
    the core.tiling.TilePlan protocol, so viability factories (and any
    future consumer) can read batch_tile/time_chunk without knowing the
    family-specific field names."""
    from repro.core import tiling
    from repro.kernels import lstm_seq, mamba_scan, wkv6 as wkv6_lib

    seq = lstm_seq.SeqBlocks(block_b=8)
    wkv = wkv6_lib.WkvBlocks(16)
    mamba = mamba_scan.MambaBlocks(block_b=4, chunk=32)
    for plan in (seq, wkv, mamba):
        assert isinstance(plan, tiling.TilePlan)
    assert seq.batch_tile == 8 and seq.time_chunk is None
    assert wkv.batch_tile == wkv.bh_tile and wkv.time_chunk == 16
    assert mamba.batch_tile == 4 and mamba.time_chunk == 32
    # something without the accessors is NOT a TilePlan
    assert not isinstance(object(), tiling.TilePlan)


def test_wkv6_choose_chunk_deprecated_alias_over_choose_blocks():
    from repro.kernels import wkv6 as wkv6_lib

    S, dk, dv = 128, 64, 64
    with pytest.warns(DeprecationWarning, match="choose_blocks"):
        legacy = wkv6_lib.choose_chunk(S, dk, dv, target=16)
    modern = wkv6_lib.choose_blocks(1, S, dk, dv, target=16)
    assert legacy == modern


def test_slot_engine_per_tick_choice_respects_two_family_viability():
    """Per-tick choice inside SlotEngine: with a faster-calibrated rwkv
    decode plan registered but bound non-viable, every tick's Decision
    picks the base plan and serving output is unaffected; with the real
    budget the same registration wins the ticks."""
    import dataclasses as dc

    import numpy as np

    from repro import steps as steps_lib
    from repro.configs import get_arch
    from repro.core import plans
    from repro.models import registry as model_registry
    from repro.partitioning import split as p_split
    from repro.serving import Request, SlotEngine

    cfg = dc.replace(get_arch("qwen2-0.5b").reduced(), n_layers=2,
                     d_model=64, n_heads=2, n_kv_heads=1, head_dim=16,
                     d_ff=128, vocab=128)
    model = model_registry.build(cfg)
    params, _ = p_split(model.init(jax.random.PRNGKey(0)))

    def run(rwkv_budget):
        engine = SlotEngine(
            model, params, n_slots=2, max_seq=32,
            extra_plans={"decode/wkv_fused":
                         lambda p, c, b: steps_lib.decode_step(cfg, p, c, b)})
        engine.scheduler.viable = plans.scheduler_viability({
            "decode/wkv_fused":
            ("chunked_scan",
             plans.rwkv_viability(128, 64, 64, vmem_budget=rwkv_budget))})
        # make the rwkv-bound plan the would-be winner of every tick
        engine.scheduler.plans["decode/wkv_fused"].base_latency_s = 1e-6
        engine.scheduler.plans["decode/base"].base_latency_s = 1e-3
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, cfg.vocab, (4,)).astype(np.int32),
                        max_new_tokens=3) for i in range(3)]
        results = engine.serve(reqs)
        assert [r.uid for r in results] == [0, 1, 2]
        ticks = [d.plan for d in engine.scheduler.decisions]
        assert ticks, "no decode ticks recorded"
        return results, ticks

    res_blocked, ticks_blocked = run(rwkv_budget=2048)
    assert set(ticks_blocked) == {"decode/base"}   # never the non-viable one
    res_open, ticks_open = run(rwkv_budget=None)
    # with the budget open the bound plan wins the tick (later ticks may
    # legitimately flip as plan.observe folds REAL latencies over the
    # seeded bases — per-tick choice staying live is the point)
    assert ticks_open[0] == "decode/wkv_fused"
    for a, b in zip(res_blocked, res_open):      # same decode fn: same tokens
        assert np.array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
def _spec():
    return {"c": jax.ShapeDtypeStruct((2, 4), jnp.float32),
            "h": jax.ShapeDtypeStruct((2, 4), jnp.float32)}


def test_pool_checkout_return_cycle():
    pool = StatePool(_spec(), capacity=3)
    a = pool.checkout()
    b = pool.checkout()
    assert pool.stats.outstanding == 2
    pool.give_back(a)
    pool.give_back(b)
    assert pool.stats.outstanding == 0
    assert pool.stats.high_water == 2


def test_pool_exhaustion_raises():
    pool = StatePool(_spec(), capacity=1)
    pool.checkout()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.checkout()


def test_pool_returns_zeroed_buffers():
    pool = StatePool(_spec(), capacity=1)
    buf = pool.checkout()
    buf = {k: v + 7.0 for k, v in buf.items()}
    pool.give_back(buf)
    again = pool.checkout()
    assert float(jnp.sum(jnp.abs(again["c"]))) == 0.0


def test_pool_allocation_accounting():
    pool = StatePool(_spec(), capacity=4)
    assert pool.stats.allocation_bytes == 4 * 2 * (2 * 4 * 4)


def test_give_back_resets_without_allocating():
    """Regression: give_back used to run ``b * 0`` per return — a fresh
    buffer per cycle despite the 'reset without allocating' docstring.  The
    reset now goes through a donated jit: the returned buffer is zeroed in
    place, the caller's handle is invalidated, and the pool never builds a
    buffer after __init__."""
    pool = StatePool(_spec(), capacity=1)
    for cycle in range(5):
        buf = pool.checkout()
        buf = {k: v + 7.0 for k, v in buf.items()}
        leaves = jax.tree.leaves(buf)
        pool.give_back(buf)
        # donation invalidated the returned handle — in-place reset
        assert all(leaf.is_deleted() for leaf in leaves), cycle
    assert pool.stats.buffers_built == 1        # no growth in live buffers
    assert pool.stats.resets == 5


def test_lane_zero_zeroes_single_lane():
    from repro.core.state import donate, lane_zero

    tree = {"c": jnp.ones((3, 2, 4)), "h": jnp.ones((3, 2, 4))}
    reset = donate(lambda t, i: lane_zero(t, i, axis=1), (0,))
    out = reset(tree, jnp.asarray(1, jnp.int32))
    assert float(jnp.sum(out["c"][:, 1])) == 0.0
    assert float(jnp.sum(out["c"][:, 0])) == 3 * 4     # untouched lane
