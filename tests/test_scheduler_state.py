"""Load-aware scheduler (paper Fig 7) and preallocated state pools (§3.2)."""
import jax.numpy as jnp
import pytest

from repro.core.scheduler import (Plan, ProcLoadSensor, Scheduler,
                                  SyntheticLoadSensor)
from repro.core.state import StatePool
import jax


def _sched(accel_base=0.03, cpu_base=0.1):
    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("accel", lambda: None, base_latency_s=accel_base,
                    shared=True, sensitivity=1.0))
    s.register(Plan("cpu", lambda: None, base_latency_s=cpu_base,
                    shared=False))
    return s


def test_low_load_prefers_accelerator():
    s = _sched()
    assert s.choose(load=0.1).plan == "accel"
    assert s.choose(load=0.4).plan == "accel"


def test_high_load_crosses_over_to_cpu():
    """Paper Fig 7: under high accelerator load the CPU path wins."""
    s = _sched()
    assert s.choose(load=0.9).plan == "cpu"


def test_crossover_point_matches_contention_model():
    # accel wins iff base/(1-load) < cpu_base  =>  load < 1 - accel/cpu
    s = _sched(accel_base=0.03, cpu_base=0.1)
    crossover = 1 - 0.03 / 0.1
    assert s.choose(load=crossover - 0.05).plan == "accel"
    assert s.choose(load=crossover + 0.05).plan == "cpu"


def test_observation_updates_base_latency():
    s = _sched()
    p = s.plans["accel"]
    for _ in range(50):
        p.observe(0.2, load=0.0)      # accel got slow
    assert s.choose(load=0.0).plan == "cpu"


def test_proc_sensor_in_range():
    v = ProcLoadSensor().load()
    assert 0.0 <= v <= 1.0


# ---------------------------------------------------------------------------
def _spec():
    return {"c": jax.ShapeDtypeStruct((2, 4), jnp.float32),
            "h": jax.ShapeDtypeStruct((2, 4), jnp.float32)}


def test_pool_checkout_return_cycle():
    pool = StatePool(_spec(), capacity=3)
    a = pool.checkout()
    b = pool.checkout()
    assert pool.stats.outstanding == 2
    pool.give_back(a)
    pool.give_back(b)
    assert pool.stats.outstanding == 0
    assert pool.stats.high_water == 2


def test_pool_exhaustion_raises():
    pool = StatePool(_spec(), capacity=1)
    pool.checkout()
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.checkout()


def test_pool_returns_zeroed_buffers():
    pool = StatePool(_spec(), capacity=1)
    buf = pool.checkout()
    buf = {k: v + 7.0 for k, v in buf.items()}
    pool.give_back(buf)
    again = pool.checkout()
    assert float(jnp.sum(jnp.abs(again["c"]))) == 0.0


def test_pool_allocation_accounting():
    pool = StatePool(_spec(), capacity=4)
    assert pool.stats.allocation_bytes == 4 * 2 * (2 * 4 * 4)
