"""The exact execution plans are the SAME function (core/lstm docstring);
the int8 plan matches within its documented error band.

Parametrized over plan x dtype x deliberately awkward shapes (odd batch,
short prime-ish T, hidden sizes that do not divide the Pallas block sizes)
so block padding, wavefront masking, and the sequence kernel's batch tiling
are all exercised off the happy path.  ``forward_sequential`` is the oracle.

``fused_seq_q8`` is excluded from the exact sweeps: its contract is the
ERROR-BAND equivalence of the Q8 section below — tight agreement with the
dequantize oracle (fp rounding of the folded per-channel scale), int8-band
agreement with the f32 plans, and straight-through gradients that match the
STE reference (ref.quantize_dequantize_ste) exactly-math.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm

# (batch, seq_len, hidden, input_dim, n_layers) — none block-aligned
SHAPES = [
    (3, 7, 48, 9, 2),      # the issue's canonical odd shape
    (1, 5, 33, 9, 3),      # B=1, hidden 33 (not even lane-aligned)
    (5, 3, 16, 40, 2),     # input_dim > hidden: P = max(D, H) padding path
]
TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=5e-2, atol=5e-2)}

#: exact-equivalence plans: everything but the oracle and the int8 plan
EXACT_PLANS = [n for n in lstm.FORWARD_PLANS
               if n not in ("sequential", "fused_seq_q8")]

#: THE documented int8 error band (ROADMAP §Quantization): per-output-
#: channel symmetric int8 bounds each dequantized weight within
#: max|w_col|/254 of f32, and the saturating LSTM nonlinearities keep the
#: recurrence from amplifying it — logits land within 5e-2 of the f32
#: plans at the paper shapes (measured headroom ~5x).  Kernel-vs-dequant-
#: oracle agreement is far tighter (fp rounding only): Q8_ORACLE_TOL.
Q8_BAND = dict(rtol=5e-2, atol=5e-2)
Q8_ORACLE_TOL = dict(rtol=1e-4, atol=1e-5)


def _setup(shape, dtype):
    b, t, h, d, n_layers = shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d),
                          jnp.dtype(dtype))
    return cfg, params, x


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", EXACT_PLANS)
def test_plan_matches_sequential(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    want = lstm.forward_sequential(params, x, cfg)
    got = lstm.FORWARD_PLANS[plan](params, x, cfg)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_plans_agree_under_jit_and_grad():
    """The plans stay equivalent through jit and as loss_fn backends."""
    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])
    grads = []
    for plan in ("sequential", "fused_seq"):
        fwd = lstm.FORWARD_PLANS[plan]
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd)))(params)
        grads.append((loss, g))
    np.testing.assert_allclose(grads[0][0], grads[1][0], rtol=1e-5,
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads[0][1]),
                    jax.tree.leaves(grads[1][1])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GRADIENT equivalence: every plan is the same function under jax.grad too
# (fused_seq via the fused reverse-sweep kernel, fused_cell via the per-cell
# oracle VJP, wavefront via plain autodiff) — the training-story guarantee.
# ---------------------------------------------------------------------------
TOL_GRAD = {"float32": dict(rtol=2e-4, atol=2e-5),
            "bfloat16": dict(rtol=8e-2, atol=8e-2)}


def _grads(plan, cfg, params, x, labels):
    fwd = lstm.FORWARD_PLANS[plan]
    _, g = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    return g


def _assert_grads_match(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    labels = jnp.arange(shape[0]) % cfg.n_classes
    want = _grads("sequential", cfg, params, x, labels)
    got = _grads(plan, cfg, params, x, labels)
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32))))
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   **TOL_GRAD[dtype])


@pytest.mark.parametrize("plan", EXACT_PLANS)
def test_grad_matches_sequential_fast(plan):
    """Quick-loop guard: the canonical odd shape, float32."""
    _assert_grads_match(plan, SHAPES[0], "float32")


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES[1:], ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", EXACT_PLANS)
def test_grad_matches_sequential_sweep(plan, shape, dtype):
    _assert_grads_match(plan, shape, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("plan", EXACT_PLANS)
def test_grad_matches_sequential_bf16_canonical(plan):
    _assert_grads_match(plan, SHAPES[0], "bfloat16")


def test_value_and_grad_dispatches_O1_in_T():
    """The fused-seq training step is O(1) Pallas dispatches in T: exactly
    one trajectory-emitting forward + one reverse-sweep backward, at every
    sequence length — vs the per-cell plan's O(T*L) forward replay."""
    from repro.analysis import count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        counts.append(count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.FORWARD_PLANS["fused_seq"]),
            params))
    assert counts == [2, 2, 2], counts

    # contrast: the per-cell plan's training step scales with T*L (pallas
    # dispatches all sit in the forward; its VJP replays the jnp oracle)
    cfg, params, x = _setup((2, 6, 16, 9, 2), "float32")
    labels = jnp.array([0, 1])
    n_cell = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg,
                               forward=lstm.FORWARD_PLANS["fused_cell"]),
        params)
    assert n_cell == 6 * 2, n_cell


# ---------------------------------------------------------------------------
# Long-T time streaming (ISSUE 4 acceptance): past the whole-T-resident VMEM
# budget the plan STREAMS the time axis instead of falling back — no
# fused_cell reroute, no oracle-VJP backward.
# ---------------------------------------------------------------------------
#: The mobile-class budget where the seed config's whole-T-resident working
#: set falls off by T=512 (bwd) / T=2048 (fwd) while the chunked table
#: stays viable — same constant the CI smoke (benchmarks/run.py
#: --stream-smoke) runs at.
from repro.core.factorization import MOBILE_VMEM_BUDGET as _STREAM_BUDGET


def test_long_T_budget_table_streams_instead_of_falling_back():
    """Pure budget math: at (T, budget) pairs where whole-T residency does
    not fit even at batch tile 1, ``choose_batch_block`` returns a viable
    ``(block_b, time_chunk)`` — and keeps the batch tile coarse."""
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    p_width = max(cfg.input_dim, cfg.hidden)
    for T, mode in ((512, "bwd"), (2048, "fwd"), (2048, "bwd")):
        nochunk = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode, allow_chunk=False)
        assert nochunk is None, (T, mode, nochunk)   # the old cliff
        blocks = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode)
        assert blocks is not None and blocks.time_chunk is not None, (T, mode)
        assert blocks.block_b == 2, blocks            # batch stays coarse
        assert seq_lib.working_set_bytes(
            T, cfg.n_layers, p_width, cfg.hidden, blocks.block_b,
            mode=mode, time_chunk=blocks.time_chunk) <= _STREAM_BUDGET


@pytest.mark.slow
def test_long_T_streamed_plan_matches_sequential():
    """Executed acceptance: at T=512 under the mobile-class budget — where
    the pre-streaming table dropped the backward to the oracle VJP — the
    plan stays fused_seq end-to-end (1 fwd dispatch, 2 train dispatches)
    and fwd + gradients match the sequential oracle."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    cfg, params, x = _setup((2, 512, 32, 9, 2), "float32")
    labels = jnp.array([0, 1])

    def fwd(p, x, cfg):
        return lstm.forward_fused_seq(p, x, cfg,
                                      vmem_budget=_STREAM_BUDGET)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: fwd(p, x, cfg))(params, x))
    n_train = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd), params)
    assert (n_fwd, n_train) == (1, 2), (n_fwd, n_train)

    want = lstm.forward_sequential(params, x, cfg)
    got = fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    gw = _grads("sequential", cfg, params, x, labels)
    _, gg = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    for a, w in zip(jax.tree.leaves(gg), jax.tree.leaves(gw)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Q8 (ISSUE 5 acceptance): the int8-weight plan's ERROR-BAND equivalence
# contract — tight vs the dequantize oracle, banded vs the f32 plans,
# exact-math straight-through gradients, O(1) dispatches, and a
# strictly-no-finer quantization-aware tiling at the mobile-class budget.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
def test_q8_plan_matches_oracle_and_band(shape):
    """The q8 plan (a) agrees with the dequantize-then-run oracle within fp
    rounding — the real kernel contract — and (b) stays inside the
    documented int8 band of the sequential f32 oracle."""
    from repro.kernels import lstm_seq as seq_lib
    from repro.kernels import ref
    from repro.partitioning import split

    cfg, params, x = _setup(shape, "float32")
    got = lstm.forward_fused_seq_q8(params, x, cfg)
    want_f32 = lstm.forward_sequential(params, x, cfg)
    assert got.shape == want_f32.shape and got.dtype == want_f32.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_f32),
                               **Q8_BAND)
    # dequantize-oracle reference for the same logits
    values, _ = split(params)
    w_stack, b_stack, p_width = seq_lib.stack_params(values["layers"],
                                                     cfg.hidden)
    xp = seq_lib.pad_input(x, p_width)
    wq, scales = ref.quantize_q8(w_stack)
    _, h = ref.lstm_seq_q8(wq, scales, b_stack, xp)
    want_q8 = h[-1] @ values["head"]["w"] + values["head"]["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_q8),
                               **Q8_ORACLE_TOL)


def test_q8_grads_match_ste_reference():
    """Straight-through training contract: grads of the q8 plan equal the
    grads of the sequential oracle run over ref.quantize_dequantize_ste
    weights — same quantized forward, identity passthrough to the masters.
    Checked at the plan level (stacking + head included)."""
    from repro.kernels import ref

    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])

    def ste_forward(p, x, cfg):
        # quantize each layer's stacked rows exactly as the plan does:
        # through the SAME stacked (L, P+H, 4H) layout
        from repro.kernels import lstm_seq as seq_lib
        from repro.partitioning import split as _split
        values, _ = _split(p)
        w_stack, b_stack, p_width = seq_lib.stack_params(values["layers"],
                                                         cfg.hidden)
        w_ste = ref.quantize_dequantize_ste(w_stack)
        xp = seq_lib.pad_input(x, p_width)
        _, h = ref.lstm_seq(w_ste, b_stack.astype(jnp.float32), xp)
        return h[-1] @ values["head"]["w"] + values["head"]["b"]

    got = _grads("fused_seq_q8", cfg, params, x, labels)
    want = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=ste_forward))(
            params)[1]
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)


def test_q8_value_and_grad_dispatches_O1_in_T():
    """Quantization happens in jnp outside the kernels: the q8 training
    step is still exactly 2 Pallas dispatches at every T, and the forward
    exactly 1."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        n = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq_q8(p, x, cfg))(params, x))
        counts.append((n, count_train_dispatches(
            lambda p: lstm.loss_fn(
                p, x, labels, cfg,
                forward=lstm.FORWARD_PLANS["fused_seq_q8"]),
            params)))
    assert counts == [(1, 2), (1, 2), (1, 2)], counts


def test_q8_budget_no_finer_than_f32_at_mobile_budget():
    """ISSUE 5 acceptance: at the 320K mobile-class budget the
    quantization-aware table returns a strictly-no-finer (block_b,
    time_chunk) than f32 at every T/mode — and strictly COARSER somewhere
    (the widened whole-T window), including a (T, mode) where f32 must
    stream but q8 stays whole-T resident."""
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    p_width = max(cfg.input_dim, cfg.hidden)
    strictly_coarser = wholeT_won = False
    for T in (32, 128, 512, 1024, 2048):
        for mode in ("fwd", "bwd"):
            f32 = seq_lib.choose_batch_block(
                2, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=_STREAM_BUDGET, mode=mode)
            q8 = seq_lib.choose_batch_block(
                2, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=_STREAM_BUDGET, mode=mode, quantized=True)
            assert q8 is not None, (T, mode)
            if f32 is None:
                strictly_coarser = True
                continue
            assert q8.block_b >= f32.block_b, (T, mode, f32, q8)
            if q8.time_chunk is None:
                if f32.time_chunk is not None:
                    strictly_coarser = wholeT_won = True
            else:
                assert f32.time_chunk is not None, (T, mode, f32, q8)
                assert q8.time_chunk >= f32.time_chunk, (T, mode, f32, q8)
                if q8.time_chunk > f32.time_chunk:
                    strictly_coarser = True
    assert strictly_coarser     # the 4x weight term must actually matter
    assert wholeT_won           # the widened whole-T-resident window
