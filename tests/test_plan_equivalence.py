"""All four execution plans are the SAME function (core/lstm docstring).

Parametrized over plan x dtype x deliberately awkward shapes (odd batch,
short prime-ish T, hidden sizes that do not divide the Pallas block sizes)
so block padding, wavefront masking, and the sequence kernel's batch tiling
are all exercised off the happy path.  ``forward_sequential`` is the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm

# (batch, seq_len, hidden, input_dim, n_layers) — none block-aligned
SHAPES = [
    (3, 7, 48, 9, 2),      # the issue's canonical odd shape
    (1, 5, 33, 9, 3),      # B=1, hidden 33 (not even lane-aligned)
    (5, 3, 16, 40, 2),     # input_dim > hidden: P = max(D, H) padding path
]
TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _setup(shape, dtype):
    b, t, h, d, n_layers = shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d),
                          jnp.dtype(dtype))
    return cfg, params, x


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_plan_matches_sequential(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    want = lstm.forward_sequential(params, x, cfg)
    got = lstm.FORWARD_PLANS[plan](params, x, cfg)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_plans_agree_under_jit_and_grad():
    """The plans stay equivalent through jit and as loss_fn backends."""
    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])
    grads = []
    for plan in ("sequential", "fused_seq"):
        fwd = lstm.FORWARD_PLANS[plan]
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd)))(params)
        grads.append((loss, g))
    np.testing.assert_allclose(grads[0][0], grads[1][0], rtol=1e-5,
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads[0][1]),
                    jax.tree.leaves(grads[1][1])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GRADIENT equivalence: every plan is the same function under jax.grad too
# (fused_seq via the fused reverse-sweep kernel, fused_cell via the per-cell
# oracle VJP, wavefront via plain autodiff) — the training-story guarantee.
# ---------------------------------------------------------------------------
TOL_GRAD = {"float32": dict(rtol=2e-4, atol=2e-5),
            "bfloat16": dict(rtol=8e-2, atol=8e-2)}


def _grads(plan, cfg, params, x, labels):
    fwd = lstm.FORWARD_PLANS[plan]
    _, g = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    return g


def _assert_grads_match(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    labels = jnp.arange(shape[0]) % cfg.n_classes
    want = _grads("sequential", cfg, params, x, labels)
    got = _grads(plan, cfg, params, x, labels)
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32))))
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   **TOL_GRAD[dtype])


@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_fast(plan):
    """Quick-loop guard: the canonical odd shape, float32."""
    _assert_grads_match(plan, SHAPES[0], "float32")


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES[1:], ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_sweep(plan, shape, dtype):
    _assert_grads_match(plan, shape, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_bf16_canonical(plan):
    _assert_grads_match(plan, SHAPES[0], "bfloat16")


def test_value_and_grad_dispatches_O1_in_T():
    """The fused-seq training step is O(1) Pallas dispatches in T: exactly
    one trajectory-emitting forward + one reverse-sweep backward, at every
    sequence length — vs the per-cell plan's O(T*L) forward replay."""
    from repro.analysis import count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        counts.append(count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.FORWARD_PLANS["fused_seq"]),
            params))
    assert counts == [2, 2, 2], counts

    # contrast: the per-cell plan's training step scales with T*L (pallas
    # dispatches all sit in the forward; its VJP replays the jnp oracle)
    cfg, params, x = _setup((2, 6, 16, 9, 2), "float32")
    labels = jnp.array([0, 1])
    n_cell = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg,
                               forward=lstm.FORWARD_PLANS["fused_cell"]),
        params)
    assert n_cell == 6 * 2, n_cell


# ---------------------------------------------------------------------------
# Long-T time streaming (ISSUE 4 acceptance): past the whole-T-resident VMEM
# budget the plan STREAMS the time axis instead of falling back — no
# fused_cell reroute, no oracle-VJP backward.
# ---------------------------------------------------------------------------
#: The mobile-class budget where the seed config's whole-T-resident working
#: set falls off by T=512 (bwd) / T=2048 (fwd) while the chunked table
#: stays viable — same constant the CI smoke (benchmarks/run.py
#: --stream-smoke) runs at.
from repro.core.factorization import MOBILE_VMEM_BUDGET as _STREAM_BUDGET


def test_long_T_budget_table_streams_instead_of_falling_back():
    """Pure budget math: at (T, budget) pairs where whole-T residency does
    not fit even at batch tile 1, ``choose_batch_block`` returns a viable
    ``(block_b, time_chunk)`` — and keeps the batch tile coarse."""
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    p_width = max(cfg.input_dim, cfg.hidden)
    for T, mode in ((512, "bwd"), (2048, "fwd"), (2048, "bwd")):
        nochunk = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode, allow_chunk=False)
        assert nochunk is None, (T, mode, nochunk)   # the old cliff
        blocks = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode)
        assert blocks is not None and blocks.time_chunk is not None, (T, mode)
        assert blocks.block_b == 2, blocks            # batch stays coarse
        assert seq_lib.working_set_bytes(
            T, cfg.n_layers, p_width, cfg.hidden, blocks.block_b,
            mode=mode, time_chunk=blocks.time_chunk) <= _STREAM_BUDGET


@pytest.mark.slow
def test_long_T_streamed_plan_matches_sequential():
    """Executed acceptance: at T=512 under the mobile-class budget — where
    the pre-streaming table dropped the backward to the oracle VJP — the
    plan stays fused_seq end-to-end (1 fwd dispatch, 2 train dispatches)
    and fwd + gradients match the sequential oracle."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    cfg, params, x = _setup((2, 512, 32, 9, 2), "float32")
    labels = jnp.array([0, 1])

    def fwd(p, x, cfg):
        return lstm.forward_fused_seq(p, x, cfg,
                                      vmem_budget=_STREAM_BUDGET)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: fwd(p, x, cfg))(params, x))
    n_train = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd), params)
    assert (n_fwd, n_train) == (1, 2), (n_fwd, n_train)

    want = lstm.forward_sequential(params, x, cfg)
    got = fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    gw = _grads("sequential", cfg, params, x, labels)
    _, gg = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    for a, w in zip(jax.tree.leaves(gg), jax.tree.leaves(gw)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=2e-4, atol=2e-4)
