"""All four execution plans are the SAME function (core/lstm docstring).

Parametrized over plan x dtype x deliberately awkward shapes (odd batch,
short prime-ish T, hidden sizes that do not divide the Pallas block sizes)
so block padding, wavefront masking, and the sequence kernel's batch tiling
are all exercised off the happy path.  ``forward_sequential`` is the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm

# (batch, seq_len, hidden, input_dim, n_layers) — none block-aligned
SHAPES = [
    (3, 7, 48, 9, 2),      # the issue's canonical odd shape
    (1, 5, 33, 9, 3),      # B=1, hidden 33 (not even lane-aligned)
    (5, 3, 16, 40, 2),     # input_dim > hidden: P = max(D, H) padding path
]
TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _setup(shape, dtype):
    b, t, h, d, n_layers = shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d),
                          jnp.dtype(dtype))
    return cfg, params, x


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_plan_matches_sequential(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    want = lstm.forward_sequential(params, x, cfg)
    got = lstm.FORWARD_PLANS[plan](params, x, cfg)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_plans_agree_under_jit_and_grad():
    """The plans stay equivalent through jit and as loss_fn backends."""
    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])
    grads = []
    for plan in ("sequential", "fused_seq"):
        fwd = lstm.FORWARD_PLANS[plan]
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd)))(params)
        grads.append((loss, g))
    np.testing.assert_allclose(grads[0][0], grads[1][0], rtol=1e-5,
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads[0][1]),
                    jax.tree.leaves(grads[1][1])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
