"""Every plan of every registered family is the SAME function (or sits
inside its documented error band) — with the sweep GENERATED from the
family-generic plan registry (core/plans.py).

``plans.value_sweep()`` / ``plans.grad_sweep()`` enumerate plans x dtypes x
deliberately awkward shapes per family (odd batch, short prime-ish T,
hidden sizes that do not divide the Pallas block sizes; for rwkv6: C=1,
C=T, non-dividing T, chunk > T), each compared leaf-wise against the
family's oracle under the plan's registered equivalence policy.  Adding a
family to the registry adds it to this sweep — nothing here is
LSTM-specific anymore.

``fused_seq_q8`` carries a band policy with no oracle-gradient contract:
its training guarantee is the ERROR-BAND Q8 section below — tight
agreement with the dequantize oracle (fp rounding of the folded
per-channel scale), int8-band agreement with the f32 plans, and
straight-through gradients that match the STE reference
(ref.quantize_dequantize_ste) exactly-math.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm, plans

#: the LSTM family's shapes, re-exported for the Q8/streaming sections
SHAPES = [c.shape for c in plans.get_family("lstm").cases]

#: exact-equivalence plans of the lstm family (the historical constant;
#: the jit/Q8 sections still iterate it)
EXACT_PLANS = [n for n, s in plans.get_family("lstm").plans.items()
               if s.policy.kind == "exact" and n != "sequential"]

Q8_BAND = plans.Q8_BAND
#: kernel-vs-dequant-oracle agreement is far tighter than the int8 band
#: (fp rounding of the folded per-channel scale only)
Q8_ORACLE_TOL = dict(rtol=1e-4, atol=1e-5)


def _setup(shape, dtype):
    b, t, h, d, n_layers = shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d),
                          jnp.dtype(dtype))
    return cfg, params, x


def _sweep_params(sweep):
    return [pytest.param(sc, id=sc.id,
                         marks=[pytest.mark.slow] if sc.heavy else [])
            for sc in sweep]


def test_registry_preserves_forward_plans():
    """Acceptance: the registry SERVES core/lstm.FORWARD_PLANS — same
    names, same functions — rather than forking them."""
    fam = plans.get_family("lstm")
    assert list(fam.plans) == list(lstm.FORWARD_PLANS)
    for name, spec in fam.plans.items():
        assert spec.fn is lstm.FORWARD_PLANS[name]
    assert fam.oracle == "sequential"


@pytest.mark.parametrize("sc", _sweep_params(plans.value_sweep()))
def test_plan_matches_oracle(sc):
    """Registry-generated value sweep: every comparable plan of every
    family, against that family's oracle, at the registered tolerance."""
    fam = plans.get_family(sc.family)
    inputs = fam.make_inputs(sc.case, sc.dtype)
    got = fam.apply(sc.plan, inputs)
    want = fam.apply(fam.oracle, inputs)
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.shape == w.shape and a.dtype == w.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   **fam.tol(sc.plan, sc.dtype),
                                   err_msg=sc.id)


def test_plans_agree_under_jit_and_grad():
    """The plans stay equivalent through jit and as loss_fn backends."""
    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])
    grads = []
    for plan in ("sequential", "fused_seq"):
        fwd = lstm.FORWARD_PLANS[plan]
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd)))(params)
        grads.append((loss, g))
    np.testing.assert_allclose(grads[0][0], grads[1][0], rtol=1e-5,
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads[0][1]),
                    jax.tree.leaves(grads[1][1])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GRADIENT equivalence: every plan is the same function under jax.grad too
# (fused_seq via the fused reverse-sweep kernel, rwkv6 chunked_scan via the
# reverse-sweep wkv kernel, fused_cell via the per-cell oracle VJP,
# wavefront via plain autodiff) — the training-story guarantee, generated
# from the registry: only (plan, dtype) pairs whose policy registers a
# grad_tol participate (the q8 plan's gradient contract is the STE test).
# ---------------------------------------------------------------------------
def _grads(plan, cfg, params, x, labels):
    fwd = lstm.FORWARD_PLANS[plan]
    _, g = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    return g


@pytest.mark.parametrize("sc", _sweep_params(plans.grad_sweep()))
def test_grad_matches_oracle(sc):
    fam = plans.get_family(sc.family)
    inputs = fam.make_inputs(sc.case, sc.dtype)
    got = fam.grads(sc.plan, inputs)
    want = fam.grads(fam.oracle, inputs)
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32))))
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   **fam.grad_tol(sc.plan, sc.dtype),
                                   err_msg=sc.id)


def test_value_and_grad_dispatches_O1_in_T():
    """The fused-seq training step is O(1) Pallas dispatches in T: exactly
    one trajectory-emitting forward + one reverse-sweep backward, at every
    sequence length — vs the per-cell plan's O(T*L) forward replay."""
    from repro.analysis import count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        counts.append(count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.FORWARD_PLANS["fused_seq"]),
            params))
    assert counts == [2, 2, 2], counts

    # contrast: the per-cell plan's training step scales with T*L (pallas
    # dispatches all sit in the forward; its VJP replays the jnp oracle)
    cfg, params, x = _setup((2, 6, 16, 9, 2), "float32")
    labels = jnp.array([0, 1])
    n_cell = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg,
                               forward=lstm.FORWARD_PLANS["fused_cell"]),
        params)
    assert n_cell == 6 * 2, n_cell


# ---------------------------------------------------------------------------
# RWKV6 dispatch counts (ISSUE 6): the chunked_scan plan honours its
# registered dispatch expectations — ONE forward dispatch, TWO per
# value_and_grad (trajectory-emitting forward + one reverse-sweep
# backward), at every T — and its sequential grid work is O(T/C), pinned by
# the family-aware grid-step counter.
# ---------------------------------------------------------------------------
def _rwkv_case(T, chunk, B=2, H=2, dk=8, dv=8):
    case = plans.Case(f"T{T}c{chunk}", (B, T, H, dk, dv, chunk))
    return plans.get_family("rwkv6").make_inputs(case, "float32")


def _rwkv_loss(args, chunk, plan="chunked_scan"):
    def loss(*a):
        out, s = plans.RWKV_PLANS[plan](*a, chunk=chunk)
        return (jnp.sum(out.astype(jnp.float32))
                + jnp.sum(s.astype(jnp.float32)))
    return loss, args


def test_rwkv_chunked_scan_dispatches_match_registry():
    """fwd_dispatches/train_dispatches registered on the PlanSpec hold at
    every T, dividing or not — a silent oracle-replay backward would show
    up as extra forward dispatches here."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    spec = plans.get_family("rwkv6").plans["chunked_scan"]
    for T in (8, 24, 23):
        args, chunk = _rwkv_case(T, 8)
        n_fwd = count_kernel_dispatches(jax.make_jaxpr(
            lambda *a: plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk))(
                *args))
        loss, a = _rwkv_loss(args, chunk)
        n_train = count_train_dispatches(loss, *a)
        assert n_fwd == spec.fwd_dispatches, (T, n_fwd)
        assert n_train == spec.train_dispatches, (T, n_train)


def test_rwkv_grid_steps_O_T_over_C():
    """count_pallas_grid_steps sees the O(T/C) sequential structure the
    dispatch count cannot: BH * ceil(T/C) forward grid steps, twice that
    for value_and_grad, and halving the chunk doubles both."""
    from repro.analysis import count_pallas_grid_steps

    B, H = 2, 2
    for T, chunk in ((24, 8), (23, 8), (24, 4)):
        args, _ = _rwkv_case(T, chunk, B=B, H=H)
        want = B * H * math.ceil(T / chunk)
        jx = jax.make_jaxpr(
            lambda *a: plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk))(
                *args)
        assert count_pallas_grid_steps(jx) == want, (T, chunk)
        loss, a = _rwkv_loss(args, chunk)
        jx2 = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0,)))(*a)
        assert count_pallas_grid_steps(jx2) == 2 * want, (T, chunk)


def test_rwkv_oracle_bwd_fallback_keeps_single_forward():
    """bwd=ORACLE_BWD (the past-budget fallback) still runs the fused
    forward kernel once; only the backward replays the jnp oracle — the
    shape plan_viability(train=True) routes to past the bwd budget."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.kernels import wkv6 as wkv6_lib

    args, chunk = _rwkv_case(16, 8)

    def plan(*a):
        return plans.RWKV_PLANS["chunked_scan"](
            *a, chunk=chunk, bwd=wkv6_lib.ORACLE_BWD)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(plan)(*args))

    def loss(*a):
        out, s = plan(*a)
        return jnp.sum(out) + jnp.sum(s)

    n_train = count_train_dispatches(loss, *args)
    assert (n_fwd, n_train) == (1, 1), (n_fwd, n_train)


# ---------------------------------------------------------------------------
# Long-T time streaming (ISSUE 4 acceptance): past the whole-T-resident VMEM
# budget the plan STREAMS the time axis instead of falling back — no
# fused_cell reroute, no oracle-VJP backward.
# ---------------------------------------------------------------------------
#: The mobile-class budget where the seed config's whole-T-resident working
#: set falls off by T=512 (bwd) / T=2048 (fwd) while the chunked table
#: stays viable — same constant the CI smoke (benchmarks/run.py
#: --stream-smoke) runs at.
from repro.core.factorization import MOBILE_VMEM_BUDGET as _STREAM_BUDGET


def test_long_T_budget_table_streams_instead_of_falling_back():
    """Pure budget math: at (T, budget) pairs where whole-T residency does
    not fit even at batch tile 1, ``choose_batch_block`` returns a viable
    ``(block_b, time_chunk)`` — and keeps the batch tile coarse."""
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    p_width = max(cfg.input_dim, cfg.hidden)
    for T, mode in ((512, "bwd"), (2048, "fwd"), (2048, "bwd")):
        nochunk = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode, allow_chunk=False)
        assert nochunk is None, (T, mode, nochunk)   # the old cliff
        blocks = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=_STREAM_BUDGET, mode=mode)
        assert blocks is not None and blocks.time_chunk is not None, (T, mode)
        assert blocks.block_b == 2, blocks            # batch stays coarse
        assert seq_lib.working_set_bytes(
            T, cfg.n_layers, p_width, cfg.hidden, blocks.block_b,
            mode=mode, time_chunk=blocks.time_chunk) <= _STREAM_BUDGET


@pytest.mark.slow
def test_long_T_streamed_plan_matches_sequential():
    """Executed acceptance: at T=512 under the mobile-class budget — where
    the pre-streaming table dropped the backward to the oracle VJP — the
    plan stays fused_seq end-to-end (1 fwd dispatch, 2 train dispatches)
    and fwd + gradients match the sequential oracle."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    cfg, params, x = _setup((2, 512, 32, 9, 2), "float32")
    labels = jnp.array([0, 1])

    def fwd(p, x, cfg):
        return lstm.forward_fused_seq(p, x, cfg,
                                      vmem_budget=_STREAM_BUDGET)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: fwd(p, x, cfg))(params, x))
    n_train = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd), params)
    assert (n_fwd, n_train) == (1, 2), (n_fwd, n_train)

    want = lstm.forward_sequential(params, x, cfg)
    got = fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    gw = _grads("sequential", cfg, params, x, labels)
    _, gg = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    for a, w in zip(jax.tree.leaves(gg), jax.tree.leaves(gw)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Q8 (ISSUE 5 acceptance): the int8-weight plan's ERROR-BAND equivalence
# contract — tight vs the dequantize oracle, banded vs the f32 plans,
# exact-math straight-through gradients, O(1) dispatches, and a
# strictly-no-finer quantization-aware tiling at the mobile-class budget.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
def test_q8_plan_matches_oracle_and_band(shape):
    """The q8 plan (a) agrees with the dequantize-then-run oracle within fp
    rounding — the real kernel contract — and (b) stays inside the
    documented int8 band of the sequential f32 oracle."""
    from repro.kernels import lstm_seq as seq_lib
    from repro.kernels import ref
    from repro.partitioning import split

    cfg, params, x = _setup(shape, "float32")
    got = lstm.forward_fused_seq_q8(params, x, cfg)
    want_f32 = lstm.forward_sequential(params, x, cfg)
    assert got.shape == want_f32.shape and got.dtype == want_f32.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_f32),
                               **Q8_BAND)
    # dequantize-oracle reference for the same logits
    values, _ = split(params)
    w_stack, b_stack, p_width = seq_lib.stack_params(values["layers"],
                                                     cfg.hidden)
    xp = seq_lib.pad_input(x, p_width)
    wq, scales = ref.quantize_q8(w_stack)
    _, h = ref.lstm_seq_q8(wq, scales, b_stack, xp)
    want_q8 = h[-1] @ values["head"]["w"] + values["head"]["b"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_q8),
                               **Q8_ORACLE_TOL)


def test_q8_grads_match_ste_reference():
    """Straight-through training contract: grads of the q8 plan equal the
    grads of the sequential oracle run over ref.quantize_dequantize_ste
    weights — same quantized forward, identity passthrough to the masters.
    Checked at the plan level (stacking + head included)."""
    from repro.kernels import ref

    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])

    def ste_forward(p, x, cfg):
        # quantize each layer's stacked rows exactly as the plan does:
        # through the SAME stacked (L, P+H, 4H) layout
        from repro.kernels import lstm_seq as seq_lib
        from repro.partitioning import split as _split
        values, _ = _split(p)
        w_stack, b_stack, p_width = seq_lib.stack_params(values["layers"],
                                                         cfg.hidden)
        w_ste = ref.quantize_dequantize_ste(w_stack)
        xp = seq_lib.pad_input(x, p_width)
        _, h = ref.lstm_seq(w_ste, b_stack.astype(jnp.float32), xp)
        return h[-1] @ values["head"]["w"] + values["head"]["b"]

    got = _grads("fused_seq_q8", cfg, params, x, labels)
    want = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=ste_forward))(
            params)[1]
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)


def test_q8_value_and_grad_dispatches_O1_in_T():
    """Quantization happens in jnp outside the kernels: the q8 training
    step is still exactly 2 Pallas dispatches at every T, and the forward
    exactly 1."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        n = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq_q8(p, x, cfg))(params, x))
        counts.append((n, count_train_dispatches(
            lambda p: lstm.loss_fn(
                p, x, labels, cfg,
                forward=lstm.FORWARD_PLANS["fused_seq_q8"]),
            params)))
    assert counts == [(1, 2), (1, 2), (1, 2)], counts


def test_q8_budget_no_finer_than_f32_at_mobile_budget():
    """ISSUE 5 acceptance: at the 320K mobile-class budget the
    quantization-aware table returns a strictly-no-finer (block_b,
    time_chunk) than f32 at every T/mode — and strictly COARSER somewhere
    (the widened whole-T window), including a (T, mode) where f32 must
    stream but q8 stays whole-T resident."""
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    p_width = max(cfg.input_dim, cfg.hidden)
    strictly_coarser = wholeT_won = False
    for T in (32, 128, 512, 1024, 2048):
        for mode in ("fwd", "bwd"):
            f32 = seq_lib.choose_batch_block(
                2, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=_STREAM_BUDGET, mode=mode)
            q8 = seq_lib.choose_batch_block(
                2, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=_STREAM_BUDGET, mode=mode, quantized=True)
            assert q8 is not None, (T, mode)
            if f32 is None:
                strictly_coarser = True
                continue
            assert q8.block_b >= f32.block_b, (T, mode, f32, q8)
            if q8.time_chunk is None:
                if f32.time_chunk is not None:
                    strictly_coarser = wholeT_won = True
            else:
                assert f32.time_chunk is not None, (T, mode, f32, q8)
                assert q8.time_chunk >= f32.time_chunk, (T, mode, f32, q8)
                if q8.time_chunk > f32.time_chunk:
                    strictly_coarser = True
    assert strictly_coarser     # the 4x weight term must actually matter
    assert wholeT_won           # the widened whole-T-resident window
