"""All four execution plans are the SAME function (core/lstm docstring).

Parametrized over plan x dtype x deliberately awkward shapes (odd batch,
short prime-ish T, hidden sizes that do not divide the Pallas block sizes)
so block padding, wavefront masking, and the sequence kernel's batch tiling
are all exercised off the happy path.  ``forward_sequential`` is the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm

# (batch, seq_len, hidden, input_dim, n_layers) — none block-aligned
SHAPES = [
    (3, 7, 48, 9, 2),      # the issue's canonical odd shape
    (1, 5, 33, 9, 3),      # B=1, hidden 33 (not even lane-aligned)
    (5, 3, 16, 40, 2),     # input_dim > hidden: P = max(D, H) padding path
]
TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=5e-2, atol=5e-2)}


def _setup(shape, dtype):
    b, t, h, d, n_layers = shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d),
                          jnp.dtype(dtype))
    return cfg, params, x


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_plan_matches_sequential(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    want = lstm.forward_sequential(params, x, cfg)
    got = lstm.FORWARD_PLANS[plan](params, x, cfg)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_plans_agree_under_jit_and_grad():
    """The plans stay equivalent through jit and as loss_fn backends."""
    cfg, params, x = _setup(SHAPES[0], "float32")
    labels = jnp.array([0, 3, 5])
    grads = []
    for plan in ("sequential", "fused_seq"):
        fwd = lstm.FORWARD_PLANS[plan]
        loss, g = jax.jit(jax.value_and_grad(
            lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd)))(params)
        grads.append((loss, g))
    np.testing.assert_allclose(grads[0][0], grads[1][0], rtol=1e-5,
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(grads[0][1]),
                    jax.tree.leaves(grads[1][1])):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# GRADIENT equivalence: every plan is the same function under jax.grad too
# (fused_seq via the fused reverse-sweep kernel, fused_cell via the per-cell
# oracle VJP, wavefront via plain autodiff) — the training-story guarantee.
# ---------------------------------------------------------------------------
TOL_GRAD = {"float32": dict(rtol=2e-4, atol=2e-5),
            "bfloat16": dict(rtol=8e-2, atol=8e-2)}


def _grads(plan, cfg, params, x, labels):
    fwd = lstm.FORWARD_PLANS[plan]
    _, g = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    return g


def _assert_grads_match(plan, shape, dtype):
    cfg, params, x = _setup(shape, dtype)
    labels = jnp.arange(shape[0]) % cfg.n_classes
    want = _grads("sequential", cfg, params, x, labels)
    got = _grads(plan, cfg, params, x, labels)
    for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == w.dtype and a.shape == w.shape
        assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32))))
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   **TOL_GRAD[dtype])


@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_fast(plan):
    """Quick-loop guard: the canonical odd shape, float32."""
    _assert_grads_match(plan, SHAPES[0], "float32")


@pytest.mark.slow
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", SHAPES[1:], ids=lambda s: "b{}t{}h{}d{}l{}"
                         .format(*s))
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_sweep(plan, shape, dtype):
    _assert_grads_match(plan, shape, dtype)


@pytest.mark.slow
@pytest.mark.parametrize("plan", [n for n in lstm.FORWARD_PLANS
                                  if n != "sequential"])
def test_grad_matches_sequential_bf16_canonical(plan):
    _assert_grads_match(plan, SHAPES[0], "bfloat16")


def test_value_and_grad_dispatches_O1_in_T():
    """The fused-seq training step is O(1) Pallas dispatches in T: exactly
    one trajectory-emitting forward + one reverse-sweep backward, at every
    sequence length — vs the per-cell plan's O(T*L) forward replay."""
    from repro.analysis import count_train_dispatches

    counts = []
    for t in (3, 12, 48):
        cfg, params, x = _setup((2, t, 16, 9, 2), "float32")
        labels = jnp.array([0, 1])
        counts.append(count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.FORWARD_PLANS["fused_seq"]),
            params))
    assert counts == [2, 2, 2], counts

    # contrast: the per-cell plan's training step scales with T*L (pallas
    # dispatches all sit in the forward; its VJP replays the jnp oracle)
    cfg, params, x = _setup((2, 6, 16, 9, 2), "float32")
    labels = jnp.array([0, 1])
    n_cell = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg,
                               forward=lstm.FORWARD_PLANS["fused_cell"]),
        params)
    assert n_cell == 6 * 2, n_cell
