"""Observability layer (src/repro/obs): trace core, metrics, measured
profiler, and the instrumented scheduler / plan-dispatch paths.

The traced-SlotEngine integration checks (token identity, per-tick spans,
zero-alloc with tracing on) live in tests/test_serving_slots.py next to
the serving fixtures; this module owns the unit surface.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (JsonlSink, ListSink, Metrics, Tracer, read_jsonl,
                       set_tracer)
from repro.obs import profile as profile_lib
from repro.obs import trace as trace_lib


@pytest.fixture
def list_sink():
    """Install a ListSink tracer globally; always restore the old one."""
    sink = ListSink()
    old = set_tracer(Tracer(sink))
    yield sink
    set_tracer(old)


# ---------------------------------------------------------------------------
# trace core
# ---------------------------------------------------------------------------
def test_default_tracer_disabled_and_noop():
    tr = Tracer()                     # no sink -> NullSink
    assert tr.enabled is False
    tr.event("x", a=1)                # must not raise, must not record
    span = tr.span("y")
    assert span is trace_lib.NULL_SPAN    # shared no-op, no allocation
    with span:
        span.set(z=2)                 # no-op


def test_span_nesting_parent_ids_and_seq_order():
    sink = ListSink()
    tr = Tracer(sink)
    with tr.span("outer", a=1) as outer:
        tr.event("evt", k="v")
        with tr.span("inner") as inner:
            inner.set(result=7)
        outer.set(done=True)
    recs = sink.records
    assert [r["name"] for r in recs] == ["evt", "inner", "outer"]
    evt, inner_r, outer_r = recs
    # events parent to the innermost OPEN span; spans carry their own id
    assert evt["type"] == "event" and evt["parent"] == outer_r["span"]
    assert inner_r["parent"] == outer_r["span"]
    assert outer_r["parent"] is None
    # spans emit at exit: child seq < parent seq, seq strictly increasing
    seqs = [r["seq"] for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert inner_r["seq"] < outer_r["seq"]
    # set() lands mid-flight attrs on the final record
    assert inner_r["attrs"] == {"result": 7}
    assert outer_r["attrs"] == {"a": 1, "done": True}
    assert outer_r["dur_s"] >= 0.0 and outer_r["dur_s"] >= inner_r["dur_s"]


def test_jsonl_round_trip_and_sanitisation(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    tr = Tracer(JsonlSink(path))
    with tr.span("s", pred=float("inf")):
        tr.event("e", nan=float("nan"), npval=np.int64(3), arr=np.arange(2))
    tr.close()
    assert tr.enabled is False        # close() disarms the tracer
    recs = read_jsonl(path)
    assert [r["name"] for r in recs] == ["e", "s"]
    # strict JSON: non-finite floats become null, numpy scalars unwrap,
    # arbitrary objects fall back to repr
    assert recs[0]["attrs"]["nan"] is None
    assert recs[0]["attrs"]["npval"] == 3
    assert isinstance(recs[0]["attrs"]["arr"], list)
    assert recs[1]["attrs"]["pred"] is None


def test_configure_installs_and_rejects_both(tmp_path):
    old = trace_lib.get_tracer()
    try:
        with pytest.raises(ValueError, match="not both"):
            trace_lib.configure(path="x", sink=ListSink())
        tr = trace_lib.configure(path=str(tmp_path / "t.jsonl"))
        assert trace_lib.get_tracer() is tr and tr.enabled
        tr.close()
    finally:
        set_tracer(old)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_metrics_counter_gauge_histogram():
    m = Metrics()
    m.counter("c").inc()
    m.counter("c").inc(4)             # get-or-create returns the same object
    m.gauge("g").set(0.5)
    h = m.histogram("h")
    for v in range(100):
        h.observe(float(v))
    assert m.counter("c").value == 5
    assert h.count == 100
    assert h.percentile(50) == 49.0   # nearest-rank
    assert h.percentile(99) == 98.0
    snap = m.snapshot()
    assert snap["counters"]["c"] == 5
    assert snap["gauges"]["g"] == 0.5
    assert snap["histograms"]["h"]["count"] == 100
    assert math.isnan(Metrics().histogram("empty").percentile(50))


def test_histogram_is_bounded():
    h = Metrics().histogram("h")
    for v in range(5000):
        h.observe(float(v))
    assert h.count == 4096            # bounded deque: old samples roll off
    assert h.percentile(100) == 4999.0


# ---------------------------------------------------------------------------
# measured profiler (tiny shapes: this is the quick-loop version of the
# CI --obs-smoke sweep)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def swept():
    from repro.core.factorization import MOBILE_VMEM_BUDGET

    return profile_lib.profile_families(
        ("lstm", "rwkv6"), vmem_budget=MOBILE_VMEM_BUDGET, repeats=1,
        warmup=1, max_points=2,
        hook_kwargs={"lstm": {"batch": 2, "seq_len": 16},
                     "rwkv6": {"seq_len": 32, "n_bh": 2, "target": 8}})


def test_profiler_sweeps_both_families(swept):
    assert swept.families() == ["lstm", "rwkv6"]
    assert swept.device_kind == profile_lib.device_kind()
    assert swept.key.endswith(f"/vmem{swept.vmem_budget}")
    for fam in ("lstm", "rwkv6"):
        pts = [p for p in swept.points if p.family == fam]
        assert len(pts) >= 2          # >= 2 tiling points per family
        for p in pts:
            assert p.measured_s > 0 and math.isfinite(p.measured_s)
            assert p.point            # tiling coordinates recorded


def test_profile_save_load_round_trip(swept, tmp_path):
    path = swept.save(str(tmp_path / "profile.json"))
    loaded = profile_lib.DeviceProfile.load(path)
    assert loaded.to_json() == swept.to_json()
    assert loaded.key == swept.key


def test_model_vs_measured_report(swept):
    rows = profile_lib.model_vs_measured(swept, threshold=3.0)
    assert len(rows) == len(swept.points)
    for r in rows:
        assert r["finite"]            # every profiled point has a model
        assert r["ratio"] > 0
    # interpret-mode Pallas on CPU vs a TPU roofline: uniformly diverged —
    # the ratio is a relative diagnostic here (ROADMAP §Observability)
    assert all(r["diverged"] for r in rows)
    with pytest.raises(ValueError, match="> 1"):
        profile_lib.model_vs_measured(swept, threshold=1.0)


def test_calibrate_consumes_profile(swept):
    from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor

    def boom():
        raise AssertionError("profiled plan must not run during calibrate")

    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("fused_seq", boom))
    s.register(Plan("chunked_scan", boom))
    s.calibrate(profile=swept.best_latencies())
    for name in ("fused_seq", "chunked_scan"):
        assert math.isfinite(s.plans[name].base_latency_s)
        assert s.plans[name].base_latency_s > 0
    # rename maps family plan names onto the scheduler's registry
    renamed = swept.best_latencies(rename={"fused_seq": "accel"})
    assert "accel" in renamed and "fused_seq" not in renamed


def test_unknown_family_hook_raises():
    from repro.core import plans as plans_lib

    fam = plans_lib.get_family("lstm")
    assert fam.profile_hook is not None
    with pytest.raises(ValueError, match="no profile_hook"):
        bare = dataclasses.replace(fam, profile_hook=None)
        orig = plans_lib.get_family
        try:
            plans_lib.get_family = lambda name: bare
            profile_lib.profile_families(("lstm",), max_points=1)
        finally:
            plans_lib.get_family = orig


# ---------------------------------------------------------------------------
# instrumented scheduler + plan dispatch
# ---------------------------------------------------------------------------
def test_scheduler_choose_and_run_emit(list_sink):
    from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor

    s = Scheduler(SyntheticLoadSensor(0.25))
    s.register(Plan("a", lambda: 1, base_latency_s=0.01, shared=True))
    s.register(Plan("b", lambda: 2, base_latency_s=0.5))
    out, d = s.run()
    assert out == 1 and d.plan == "a"
    names = [r["name"] for r in list_sink.records]
    assert names == ["sched/choose", "sched/run"]
    choose, run = list_sink.records
    assert choose["attrs"]["plan"] == "a"
    assert choose["attrs"]["load"] == 0.25
    assert math.isfinite(choose["attrs"]["predicted_s"])
    assert run["type"] == "span"
    assert run["attrs"]["plan"] == "a" and run["attrs"]["latency_s"] > 0


def test_scheduler_calibrate_emits_source(list_sink):
    from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor

    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("seeded", lambda: None))
    s.register(Plan("timed", lambda: None))
    s.calibrate(repeats=1, profile={"seeded": 0.003})
    evts = {r["attrs"]["plan"]: r["attrs"]["source"]
            for r in list_sink.records if r["name"] == "sched/calibrate"}
    assert evts == {"seeded": "profile", "timed": "measured"}


def test_lstm_dispatch_event_records_tiling(list_sink):
    from repro.configs.mobirnn_lstm import LSTMConfig
    from repro.core import lstm

    cfg = dataclasses.replace(LSTMConfig(), seq_len=8)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((2, cfg.seq_len, cfg.input_dim), jnp.float32)
    lstm.forward_fused_seq(params, x, cfg)
    evts = [r for r in list_sink.records if r["name"] == "plan/dispatch"]
    assert len(evts) == 1
    a = evts[0]["attrs"]
    assert a["family"] == "lstm" and a["plan"] == "fused_seq"
    assert a["block_b"] >= 1 and (a["batch"], a["seq_len"]) == (2, 8)
    assert "fallback" not in a


def test_lstm_dispatch_event_flags_fallback(list_sink):
    from repro.configs.mobirnn_lstm import LSTMConfig
    from repro.core import lstm

    cfg = dataclasses.replace(LSTMConfig(), seq_len=4)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((1, cfg.seq_len, cfg.input_dim), jnp.float32)
    # a budget the weight stack itself cannot fit: the silent per-cell
    # fallback must become a visible dispatch event
    lstm.forward_fused_seq(params, x, cfg, vmem_budget=64)
    evts = [r for r in list_sink.records if r["name"] == "plan/dispatch"]
    assert len(evts) == 1
    assert evts[0]["attrs"]["fallback"] == "fused_cell"


def test_rwkv_dispatch_event(list_sink):
    from repro.kernels import wkv6 as wkv6_lib

    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    n_bh, T, dk, dv = 2, 8, 4, 4
    r = jax.random.normal(ks[0], (n_bh, T, dk))
    k = jax.random.normal(ks[1], (n_bh, T, dk))
    v = jax.random.normal(ks[2], (n_bh, T, dv))
    logw = -jnp.exp(jax.random.normal(ks[3], (n_bh, T, dk)))
    u = jax.random.normal(ks[4], (n_bh, dk))
    state = jnp.zeros((n_bh, dk, dv))
    wkv6_lib.wkv6(r, k, v, logw, u, state, chunk=4)
    evts = [rec for rec in list_sink.records
            if rec["name"] == "plan/dispatch"]
    assert len(evts) == 1
    a = evts[0]["attrs"]
    assert a["family"] == "rwkv6" and a["plan"] == "chunked_scan"
    assert a["chunk"] == 4 and a["seq_len"] == T and a["n_bh"] == n_bh


def test_disabled_tracer_changes_nothing():
    """Tracing off vs on must be bit-identical through the fused plan."""
    from repro.configs.mobirnn_lstm import LSTMConfig
    from repro.core import lstm

    cfg = dataclasses.replace(LSTMConfig(), seq_len=8)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((2, cfg.seq_len, cfg.input_dim), jnp.float32)
    base = lstm.forward_fused_seq(params, x, cfg)      # NullSink default
    old = set_tracer(Tracer(ListSink()))
    try:
        traced = lstm.forward_fused_seq(params, x, cfg)
    finally:
        set_tracer(old)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(traced))
