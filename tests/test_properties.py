"""Property-based tests (hypothesis) on system invariants.

hypothesis is an OPTIONAL dev dependency (requirements-dev.txt); without it
this module must skip at collection, not kill the whole tier-1 run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import factorization as fz
from repro.core import wavefront
from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.core.state import StatePool
from repro.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# wkv6: chunk size never changes results; decay monotonicity
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, 24), st.integers(2, 12), st.integers(0, 2 ** 31 - 1))
def test_wkv6_chunk_invariance(chunk_seed, dk, seed):
    T = 24
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r, k = (jax.random.normal(ks[i], (T, dk)) for i in range(2))
    v = jax.random.normal(ks[2], (T, dk))
    logw = -jnp.exp(jax.random.normal(ks[3], (T, dk)))
    u = jax.random.normal(ks[4], (dk,))
    s0 = jax.random.normal(ks[5], (dk, dk)) * 0.3
    chunk = [c for c in range(1, T + 1) if T % c == 0][chunk_seed % 4]
    o1, s1 = ref.wkv6(r, k, v, logw, u, s0, chunk=chunk)
    o2, s2 = ref.wkv6_stepwise(r, k, v, logw, u, s0)
    np.testing.assert_allclose(o1, o2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(s1, s2, rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_wkv6_state_decays_to_kv_sum_bound(seed):
    """With zero inputs after warmup and logw<0, the state magnitude must
    shrink monotonically (pure decay)."""
    dk = 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = jax.random.normal(ks[0], (dk, dk))
    logw = -jnp.exp(jax.random.normal(ks[1], (4, dk)))
    zeros = jnp.zeros((4, dk))
    _, s_next = ref.wkv6_stepwise(zeros, zeros, zeros, logw,
                                  jnp.zeros((dk,)), s)
    assert float(jnp.sum(jnp.abs(s_next))) <= float(jnp.sum(jnp.abs(s))) + 1e-5


# ---------------------------------------------------------------------------
# decode attention: padding positions never influence the output
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, 30), st.integers(0, 2 ** 31 - 1))
def test_decode_attn_padding_invariance(length, seed):
    B, H, S, dh = 1, 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    kc = jax.random.normal(ks[1], (B, S, H, dh))
    vc = jax.random.normal(ks[2], (B, S, H, dh))
    garbage = jax.random.normal(ks[3], (B, S, H, dh)) * 100
    lens = jnp.array([length], jnp.int32)
    mask = (jnp.arange(S) < length)[None, :, None, None]
    out1 = ref.decode_attn(q, kc, vc, lens)
    out2 = ref.decode_attn(q, jnp.where(mask, kc, garbage),
                           jnp.where(mask, vc, garbage), lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# state pool: capacity conservation under arbitrary checkout/return traces
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.lists(st.booleans(), min_size=1, max_size=40), st.integers(1, 5))
def test_pool_conservation(trace, capacity):
    pool = StatePool({"x": jax.ShapeDtypeStruct((2,), jnp.float32)},
                     capacity=capacity)
    held = []
    for take in trace:
        if take:
            if pool.stats.outstanding < capacity:
                held.append(pool.checkout())
            else:
                try:
                    pool.checkout()
                    assert False, "must raise at capacity"
                except RuntimeError:
                    pass
        elif held:
            pool.give_back(held.pop())
        assert 0 <= pool.stats.outstanding <= capacity
        assert pool.stats.outstanding == len(held)
    assert pool.stats.high_water <= capacity


# ---------------------------------------------------------------------------
# scheduler: decision is monotone in load (once CPU wins, it keeps winning)
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.floats(1e-4, 1.0), st.floats(1e-4, 1.0))
def test_scheduler_monotone_in_load(accel, cpu):
    s = Scheduler(SyntheticLoadSensor(0.0))
    s.register(Plan("accel", lambda: None, base_latency_s=accel, shared=True))
    s.register(Plan("cpu", lambda: None, base_latency_s=cpu, shared=False))
    picks = [s.choose(load=l / 20).plan for l in range(21)]
    switched = False
    for p in picks:
        if p == "cpu":
            switched = True
        elif switched:
            assert False, f"non-monotone decision sequence {picks}"


# ---------------------------------------------------------------------------
# wavefront width / factorization properties
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(1, 64), st.integers(1, 512))
def test_wavefront_width_bounds(layers, seq):
    w = wavefront.wavefront_width(layers, seq)
    assert 1 <= w <= min(layers, seq)
    assert wavefront.live_buffers(layers, seq) == 2 * w
    assert wavefront.live_buffers(layers, seq) <= 2 * layers * seq


@settings(**SETTINGS)
@given(st.integers(16, 8192), st.integers(16, 16384), st.integers(16, 8192))
def test_choose_block_always_fits(m, n, k):
    bm, bn, bk = fz.choose_block(m, n, k)
    ws = 2 * (bm * bk + bk * bn) + 4 * bm * bn
    assert (ws <= fz.DEFAULT_VMEM_BUDGET
            or (bm == fz.MXU_ALIGN and bn == fz.MXU_ALIGN
                and bk == fz.MXU_ALIGN))
    assert bm % fz.MXU_ALIGN == 0 and bn % fz.MXU_ALIGN == 0


# ---------------------------------------------------------------------------
# lstm cell: sigmoid gating bounds the cell state growth
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_lstm_cell_state_bound(seed):
    """|c'| <= |c| + 1 elementwise (f,i in (0,1), tanh in (-1,1))."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    D = H = 8
    w = jax.random.normal(ks[0], (D + H, 4 * H))
    b = jax.random.normal(ks[1], (4 * H,))
    x = jax.random.normal(ks[2], (3, D)) * 10
    c = jax.random.normal(ks[3], (3, H)) * 10
    h = jax.random.normal(ks[4], (3, H))
    c2, h2 = ref.lstm_cell(w, b, x, c, h)
    assert bool(jnp.all(jnp.abs(c2) <= jnp.abs(c) + 1.0 + 1e-5))
    assert bool(jnp.all(jnp.abs(h2) <= 1.0 + 1e-6))
