"""kernels/lstm_seq.py against its oracle: degenerate shapes, the VMEM
budget fallback, dispatch-count guarantees, and gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import count_kernel_dispatches
from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm
from repro.kernels import lstm_seq, ref


def _make(n_layers, hidden, input_dim, batch, seq, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    layers = []
    for i in range(n_layers):
        in_dim = input_dim if i == 0 else hidden
        kw, kb = jax.random.split(jax.random.fold_in(key, i))
        layers.append({
            "w": (jax.random.normal(kw, (in_dim + hidden, 4 * hidden))
                  * 0.3).astype(dtype),
            "b": (jax.random.normal(kb, (4 * hidden,)) * 0.1).astype(dtype),
        })
    x = jax.random.normal(jax.random.fold_in(key, 99),
                          (batch, seq, input_dim), dtype)
    w, b, p_width = lstm_seq.stack_params(layers, hidden)
    xp = lstm_seq.pad_input(x, p_width)
    return w, b, xp, p_width


@pytest.mark.parametrize("shape", [
    (2, 32, 9, 3, 7),      # paper-ish, odd batch/seq
    (1, 8, 5, 2, 1),       # T=1 degenerate
    (1, 16, 16, 4, 6),     # L=1, D == H (no padding)
    (3, 16, 40, 5, 4),     # input_dim > hidden (P = D path)
], ids=["odd", "T1", "L1", "DgtH"])
def test_matches_oracle(shape):
    w, b, xp, _ = _make(*shape)
    c_k, h_k = lstm_seq.lstm_seq(w, b, xp)
    c_r, h_r = ref.lstm_seq(w, b, xp)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-5)


def test_batch_tiling_invariance():
    """Explicit small batch tiles (grid > 1, non-dividing) change nothing."""
    w, b, xp, _ = _make(2, 24, 9, 5, 6)
    ref_out = lstm_seq.lstm_seq(w, b, xp)
    for block_b in (1, 2, 3, 5, 8):
        got = lstm_seq.lstm_seq(w, b, xp, block_b=block_b)
        for a, r in zip(got, ref_out):
            np.testing.assert_allclose(a, r, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# VMEM budget routing
# ---------------------------------------------------------------------------
def test_choose_batch_block_budget():
    # generous budget: viable, batch tile at most the batch
    bm = lstm_seq.choose_batch_block(8, 128, 2, 32, 32)
    assert bm is not None and 1 <= bm <= 8
    # shrink the budget until only smaller tiles fit
    ws_full = lstm_seq.working_set_bytes(128, 2, 32, 32, 8)
    bm_small = lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                           vmem_budget=ws_full - 1)
    assert bm_small is not None and bm_small < 8
    # budget below the bare weight stack: not viable at all
    assert lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                       vmem_budget=1024) is None


def test_forward_fused_seq_fallback_matches_and_redispatches():
    """Past the VMEM budget, forward_fused_seq must (a) still agree with the
    sequential oracle and (b) actually route to the per-cell kernel — seen
    as the dispatch count jumping from 1 to T*L."""
    cfg = LSTMConfig(seq_len=6)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6, cfg.input_dim))
    want = lstm.forward_sequential(params, x, cfg)

    fast = lstm.forward_fused_seq(params, x, cfg)
    np.testing.assert_allclose(fast, want, rtol=1e-5, atol=1e-5)
    fallback = lstm.forward_fused_seq(params, x, cfg, vmem_budget=256)
    np.testing.assert_allclose(fallback, want, rtol=1e-5, atol=1e-5)

    n_fast = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x))
    n_fall = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: lstm.forward_fused_seq(p, x, cfg, vmem_budget=256))(
            params, x))
    assert n_fast == 1
    assert n_fall == cfg.seq_len * cfg.n_layers


def test_dispatch_count_is_constant_in_T():
    cfg = LSTMConfig()
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    counts = []
    for t in (2, 16, 64):
        x = jnp.zeros((2, t, cfg.input_dim))
        counts.append(count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x)))
    assert counts == [1, 1, 1]


# ---------------------------------------------------------------------------
# Gradient flow (custom VJP, interpret mode)
# ---------------------------------------------------------------------------
def test_grad_matches_reference():
    w, b, xp, _ = _make(2, 16, 9, 3, 5)

    def loss(fn):
        def inner(w, b, xp):
            c, h = fn(w, b, xp)
            return jnp.sum(h[-1] ** 2) + 0.5 * jnp.sum(c ** 2)
        return inner

    gk = jax.grad(loss(lstm_seq.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    gr = jax.grad(loss(ref.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(gk, gr):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)
    # gradients reach every input: none are identically zero
    assert all(float(jnp.max(jnp.abs(g))) > 0 for g in gk)
