"""kernels/lstm_seq.py against its oracle: degenerate shapes, the VMEM
budget fallback, dispatch-count guarantees, and gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import count_kernel_dispatches
from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm
from repro.kernels import lstm_seq, ref


def _make(n_layers, hidden, input_dim, batch, seq, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    layers = []
    for i in range(n_layers):
        in_dim = input_dim if i == 0 else hidden
        kw, kb = jax.random.split(jax.random.fold_in(key, i))
        layers.append({
            "w": (jax.random.normal(kw, (in_dim + hidden, 4 * hidden))
                  * 0.3).astype(dtype),
            "b": (jax.random.normal(kb, (4 * hidden,)) * 0.1).astype(dtype),
        })
    x = jax.random.normal(jax.random.fold_in(key, 99),
                          (batch, seq, input_dim), dtype)
    w, b, p_width = lstm_seq.stack_params(layers, hidden)
    xp = lstm_seq.pad_input(x, p_width)
    return w, b, xp, p_width


@pytest.mark.parametrize("shape", [
    (2, 32, 9, 3, 7),      # paper-ish, odd batch/seq
    (1, 8, 5, 2, 1),       # T=1 degenerate
    (1, 16, 16, 4, 6),     # L=1, D == H (no padding)
    (3, 16, 40, 5, 4),     # input_dim > hidden (P = D path)
], ids=["odd", "T1", "L1", "DgtH"])
def test_matches_oracle(shape):
    w, b, xp, _ = _make(*shape)
    c_k, h_k = lstm_seq.lstm_seq(w, b, xp)
    c_r, h_r = ref.lstm_seq(w, b, xp)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-5)


def test_batch_tiling_invariance():
    """Explicit small batch tiles (grid > 1, non-dividing) change nothing."""
    w, b, xp, _ = _make(2, 24, 9, 5, 6)
    ref_out = lstm_seq.lstm_seq(w, b, xp)
    for block_b in (1, 2, 3, 5, 8):
        got = lstm_seq.lstm_seq(w, b, xp, block_b=block_b)
        for a, r in zip(got, ref_out):
            np.testing.assert_allclose(a, r, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# VMEM budget routing
# ---------------------------------------------------------------------------
def test_choose_batch_block_budget():
    # generous budget: viable, whole-T resident (no streaming machinery)
    blocks = lstm_seq.choose_batch_block(8, 128, 2, 32, 32)
    assert blocks is not None and 1 <= blocks.block_b <= 8
    assert blocks.time_chunk is None
    # shrink the budget below whole-T residency: the table STREAMS the time
    # axis at the same coarse batch tile instead of shrinking it
    ws_full = lstm_seq.working_set_bytes(128, 2, 32, 32, blocks.block_b)
    streamed = lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                           vmem_budget=ws_full - 1)
    assert streamed is not None and streamed.block_b == blocks.block_b
    assert streamed.time_chunk is not None and streamed.time_chunk < 128
    assert lstm_seq.working_set_bytes(
        128, 2, 32, 32, streamed.block_b,
        time_chunk=streamed.time_chunk) <= ws_full - 1
    # allow_chunk=False restores the pre-streaming table: shrink bm or bust
    nochunk = lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                          vmem_budget=ws_full - 1,
                                          allow_chunk=False)
    assert nochunk is None or nochunk.block_b < blocks.block_b
    # budget below the bare weight stack: not viable at all — the ONLY
    # remaining "on None" row of the decision table
    assert lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                       vmem_budget=1024) is None


def test_forward_fused_seq_fallback_matches_and_redispatches():
    """Past the VMEM budget, forward_fused_seq must (a) still agree with the
    sequential oracle and (b) actually route to the per-cell kernel — seen
    as the dispatch count jumping from 1 to T*L."""
    cfg = LSTMConfig(seq_len=6)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6, cfg.input_dim))
    want = lstm.forward_sequential(params, x, cfg)

    fast = lstm.forward_fused_seq(params, x, cfg)
    np.testing.assert_allclose(fast, want, rtol=1e-5, atol=1e-5)
    fallback = lstm.forward_fused_seq(params, x, cfg, vmem_budget=256)
    np.testing.assert_allclose(fallback, want, rtol=1e-5, atol=1e-5)

    n_fast = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x))
    n_fall = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: lstm.forward_fused_seq(p, x, cfg, vmem_budget=256))(
            params, x))
    assert n_fast == 1
    assert n_fall == cfg.seq_len * cfg.n_layers


def test_dispatch_count_is_constant_in_T():
    cfg = LSTMConfig()
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    counts = []
    for t in (2, 16, 64):
        x = jnp.zeros((2, t, cfg.input_dim))
        counts.append(count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x)))
    assert counts == [1, 1, 1]


# ---------------------------------------------------------------------------
# Gradient flow (custom VJP: fused reverse-sweep kernel + oracle fallback)
# ---------------------------------------------------------------------------
def _loss(fn):
    def inner(w, b, xp):
        c, h = fn(w, b, xp)
        return jnp.sum(h[-1] ** 2) + 0.5 * jnp.sum(c ** 2)
    return inner


def test_grad_matches_reference():
    w, b, xp, _ = _make(2, 16, 9, 3, 5)

    gk = jax.grad(_loss(lstm_seq.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    gr = jax.grad(_loss(ref.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(gk, gr):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)
    # gradients reach every input: none are identically zero
    assert all(float(jnp.max(jnp.abs(g))) > 0 for g in gk)


def test_traj_forward_matches_oracle_contract():
    """The trajectory-emitting forward is the residual contract: final
    (c, h) identical to the plain kernel, trajectories equal to the f32
    values the oracle scan actually carries (NOT cast to x.dtype)."""
    for shape in [(2, 32, 9, 3, 7), (1, 8, 5, 2, 1), (3, 16, 40, 5, 4)]:
        w, b, xp, _ = _make(*shape)
        c, h, ct, ht = lstm_seq._lstm_seq_traj_call(w, b, xp, 2, True)
        c_r, h_r, ct_r, ht_r = ref.lstm_seq_traj(w, b, xp)
        assert ct.dtype == ht.dtype == jnp.float32
        T, L = xp.shape[1], w.shape[0]
        assert ct.shape == (T, L, xp.shape[0], w.shape[-1] // 4)
        np.testing.assert_allclose(c, c_r, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(h, h_r, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(ct, ct_r, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(ht, ht_r, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [
    (2, 32, 9, 3, 7),      # paper-ish, odd batch/seq
    (1, 8, 5, 2, 1),       # T=1 degenerate
    (1, 16, 16, 4, 6),     # L=1, D == H (no padding)
    (3, 16, 40, 5, 4),     # input_dim > hidden (P = D path)
], ids=["odd", "T1", "L1", "DgtH"])
def test_bwd_kernel_matches_oracle_grads(shape):
    """The fused reverse-sweep kernel reproduces the oracle VJP exactly on
    every degenerate shape the forward is tested on."""
    w, b, xp, _ = _make(*shape)
    gk = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, bwd_block_b=2)), argnums=(0, 1, 2))(w, b, xp)
    gr = jax.grad(_loss(ref.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)


def test_bwd_batch_tiling_invariance():
    """Backward batch tiles (grid > 1, non-dividing — the masked dw/db
    accumulation path) change nothing."""
    w, b, xp, _ = _make(2, 24, 9, 5, 6)
    gr = jax.grad(_loss(ref.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for block_b in (1, 2, 3, 5, 8):
        gk = jax.grad(_loss(lambda w, b, x, bb=block_b: lstm_seq.lstm_seq(
            w, b, x, bwd_block_b=bb)), argnums=(0, 1, 2))(w, b, xp)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)


def test_bwd_oracle_fallback_forced_and_automatic():
    """bwd_block_b=ORACLE_BWD forces the oracle VJP (same grads, and the
    plain — residual-free — forward kernel); choose_batch_block(mode='bwd')
    returning None is the automatic trigger."""
    w, b, xp, _ = _make(2, 16, 9, 3, 5)
    g_forced = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, bwd_block_b=lstm_seq.ORACLE_BWD)),
        argnums=(0, 1, 2))(w, b, xp)
    g_kernel = jax.grad(_loss(lstm_seq.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(g_forced, g_kernel):
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)
    # bwd-mode budget is strictly larger than fwd-mode: there is a budget
    # window where the forward fits but the backward must fall back
    fwd_ws = lstm_seq.working_set_bytes(5, 2, 16, 16, 3, mode="fwd")
    bwd_ws = lstm_seq.working_set_bytes(5, 2, 16, 16, 3, mode="bwd")
    assert bwd_ws > fwd_ws
    assert lstm_seq.choose_batch_block(
        3, 5, 2, 16, 16, vmem_budget=fwd_ws) == lstm_seq.SeqBlocks(3, None)
    # at short T the bwd set is dominated by the dw/db accumulators, which
    # time-chunking cannot shrink — still None under the fwd-sized budget
    assert lstm_seq.choose_batch_block(3, 5, 2, 16, 16, vmem_budget=fwd_ws,
                                       mode="bwd") is None


def test_forward_fused_seq_bwd_window_falls_back_to_oracle():
    """Plan-level acceptance: with a VMEM budget inside the window where
    the forward fits but the backward does not, forward_fused_seq keeps the
    fused forward (1 dispatch) and its VJP drops to the oracle (0 kernel
    dispatches) — grads unchanged."""
    from repro.analysis import count_train_dispatches

    cfg = LSTMConfig(seq_len=6)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6, cfg.input_dim))
    labels = jnp.array([0, 1, 2])
    p_width = max(cfg.input_dim, cfg.hidden)
    budget = lstm_seq.working_set_bytes(6, cfg.n_layers, p_width,
                                        cfg.hidden, 3, mode="fwd")
    assert lstm_seq.choose_batch_block(3, 6, cfg.n_layers, p_width,
                                       cfg.hidden, vmem_budget=budget,
                                       mode="bwd") is None

    def loss(p, vmem_budget=None):
        return lstm.loss_fn(p, x, labels, cfg,
                            forward=lambda p, x, cfg: lstm.forward_fused_seq(
                                p, x, cfg, vmem_budget=vmem_budget))

    _, g_window = jax.value_and_grad(lambda p: loss(p, budget))(params)
    _, g_full = jax.value_and_grad(loss)(params)
    for a, r in zip(jax.tree.leaves(g_window), jax.tree.leaves(g_full)):
        np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)
    assert count_train_dispatches(lambda p: loss(p, budget), params) == 1
    assert count_train_dispatches(loss, params) == 2


def test_train_dispatch_count_O1():
    """value_and_grad of the fused-seq loss is exactly 2 dispatches (one
    trajectory-emitting forward + one reverse sweep), independent of T; the
    oracle fallback still has the single fused forward but an O(T*L)
    backward replay."""
    from repro.analysis import count_train_dispatches

    counts = []
    for t in (4, 16):
        w, b, xp, _ = _make(2, 8, 5, 2, t)
        counts.append(count_train_dispatches(
            lambda w: _loss(lstm_seq.lstm_seq)(w, b, xp), w))
    assert counts == [2, 2]

    w, b, xp, _ = _make(2, 8, 5, 2, 4)
    n_fallback = count_train_dispatches(
        lambda w: _loss(lambda *a: lstm_seq.lstm_seq(
            *a, bwd_block_b=lstm_seq.ORACLE_BWD))(w, b, xp), w)
    assert n_fallback == 1      # oracle bwd is jnp-only: just the fwd kernel


# ---------------------------------------------------------------------------
# Time streaming (double-buffered chunk pipeline): chunking changes data
# movement ONLY — every chunked kernel is bit-identical to its
# whole-T-resident twin, including across chunk boundaries.
# ---------------------------------------------------------------------------
# T=7 makes tc=2/3 non-dividing (odd tail chunk), tc=7 the single-chunk
# degenerate (tc=T), and tc=16 the clamped-past-T case.
@pytest.mark.parametrize("tc", [1, 2, 3, 7, 16])
def test_chunked_forward_bit_identical(tc):
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    want = lstm_seq.lstm_seq(w, b, xp, block_b=2)
    got = lstm_seq.lstm_seq(w, b, xp, block_b=2, time_chunk=tc)
    for a, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


@pytest.mark.parametrize("tc", [1, 3, 7])
def test_chunked_traj_bit_identical(tc):
    """The streamed trajectory-emitting forward honours the residual
    contract exactly: final state AND both (T, L, B, H) f32 trajectories
    equal the whole-T-resident kernel's bit-for-bit (the backward's gate
    recompute depends on it)."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    want = lstm_seq._lstm_seq_traj_call(w, b, xp, 2, True)
    got = lstm_seq._lstm_seq_traj_call(w, b, xp, 2, True, time_chunk=tc)
    for a, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


@pytest.mark.parametrize("tc", [1, 2, 3, 7])
def test_chunked_grads_bit_identical(tc):
    """Carry regression: the (c, h) carry crossing forward chunk boundaries
    and the (dc, dh) carry crossing reverse-sweep chunk boundaries leave
    gradients EXACTLY equal to the unchunked kernels' — streamed training
    is the same function, not an approximation of it."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    g_res = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, bwd_block_b=2)), argnums=(0, 1, 2))(w, b, xp)
    g_chn = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, bwd_block_b=2, bwd_time_chunk=tc)),
        argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(g_chn, g_res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_chunked_bwd_batch_tiling_invariance():
    """Streaming composes with batch tiling: non-dividing batch tiles (the
    masked shared-accumulator path) under chunked fwd AND bwd still match
    the oracle grads."""
    w, b, xp, _ = _make(2, 16, 9, 5, 6)
    gr = jax.grad(_loss(ref.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for block_b in (2, 3, 5):
        gk = jax.grad(_loss(lambda w, b, x, bb=block_b: lstm_seq.lstm_seq(
            w, b, x, block_b=bb, time_chunk=2, bwd_block_b=bb,
            bwd_time_chunk=2)), argnums=(0, 1, 2))(w, b, xp)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(a, r, rtol=1e-5, atol=1e-5)


def test_explicit_time_chunk_survives_auto_block_b():
    """Regression: ``time_chunk``/``bwd_time_chunk`` given WITHOUT a batch
    tile must still stream — the auto-chosen ``block_b`` must not silently
    overwrite the caller's layout with whole-T residency."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    jx_resident = str(jax.make_jaxpr(
        lambda w, b, x: lstm_seq.lstm_seq(w, b, x))(w, b, xp))
    jx_streamed = str(jax.make_jaxpr(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, time_chunk=3))(w, b, xp))
    assert jx_streamed != jx_resident        # streaming actually engaged
    got = lstm_seq.lstm_seq(w, b, xp, time_chunk=3)
    want = lstm_seq.lstm_seq(w, b, xp)
    for a, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
    g_stream = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq(
        w, b, x, bwd_time_chunk=3)), argnums=(0, 1, 2))(w, b, xp)
    g_res = jax.grad(_loss(lstm_seq.lstm_seq), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(g_stream, g_res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_chunked_dispatch_counts_unchanged():
    """The chunk loop lives INSIDE the kernel: streaming never multiplies
    dispatches — still 1 forward, still 2 per value_and_grad."""
    from repro.analysis import count_train_dispatches

    w, b, xp, _ = _make(2, 8, 5, 2, 6)
    n = count_kernel_dispatches(jax.make_jaxpr(
        lambda w, b, x: lstm_seq.lstm_seq(
            w, b, x, block_b=2, time_chunk=2))(w, b, xp))
    assert n == 1
    n_train = count_train_dispatches(
        lambda w: _loss(lambda *a: lstm_seq.lstm_seq(
            *a, block_b=2, time_chunk=2, bwd_block_b=2,
            bwd_time_chunk=2))(w, b, xp), w)
    assert n_train == 2


# ---------------------------------------------------------------------------
# Int8-weight kernels (fused_seq_q8): quantize/dequantize contract, oracle
# agreement, straight-through gradients, chunked bit-identity, and the
# quantization-aware budget table.
# ---------------------------------------------------------------------------
def test_q8_quantize_contract():
    """Per-output-channel symmetric int8: one f32 scale per (layer, gate
    column), |wq| <= 127, and dequantization bounded by half a quantization
    step per element."""
    w, _, _, _ = _make(2, 24, 9, 3, 5)
    wq, scales = ref.quantize_q8(w)
    assert wq.dtype == jnp.int8 and wq.shape == w.shape
    assert scales.dtype == jnp.float32
    assert scales.shape == (w.shape[0], w.shape[-1])
    assert int(jnp.max(jnp.abs(wq.astype(jnp.int32)))) <= 127
    wdq = ref.dequantize_q8(wq, scales)
    err = jnp.abs(wdq - w)
    assert float(jnp.max(err - scales[:, None, :] / 2)) <= 1e-6
    # symmetric: quantizing -w flips the codes, same scales
    wq_neg, scales_neg = ref.quantize_q8(-w)
    np.testing.assert_array_equal(np.asarray(scales_neg), np.asarray(scales))
    np.testing.assert_array_equal(np.asarray(wq_neg),
                                  -np.asarray(wq, np.int32))


@pytest.mark.parametrize("shape", [
    (2, 32, 9, 3, 7),      # paper-ish, odd batch/seq
    (1, 8, 5, 2, 1),       # T=1 degenerate
    (1, 16, 16, 4, 6),     # L=1, D == H (no padding)
    (3, 16, 40, 5, 4),     # input_dim > hidden (P = D path)
], ids=["odd", "T1", "L1", "DgtH"])
def test_q8_matches_dequant_oracle(shape):
    """The q8 kernel folds the per-channel scale into the pre-activations;
    vs the dequantize-then-run oracle that is an fp-rounding band, nothing
    coarser."""
    w, b, xp, _ = _make(*shape)
    wq, scales = ref.quantize_q8(w)
    c_k, h_k = lstm_seq.lstm_seq_q8(w, b, xp)
    c_r, h_r = ref.lstm_seq_q8(wq, scales, b, xp)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-4, atol=1e-5)


def test_q8_traj_matches_oracle_contract():
    """The q8 trajectory-emitting forward honours the same residual layout
    as the f32 one (f32 (T, L, B, H) post-step states) against the
    dequantize traj oracle."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    wq, scales = ref.quantize_q8(w)
    wq_arr, s_arr = jnp.asarray(wq), jnp.asarray(scales)
    c, h, ct, ht = lstm_seq._lstm_seq_traj_call(wq_arr, b, xp, 2, True,
                                                scales=s_arr)
    c_r, h_r, ct_r, ht_r = ref.lstm_seq_q8_traj(wq_arr, s_arr, b, xp)
    assert ct.dtype == ht.dtype == jnp.float32
    np.testing.assert_allclose(c, c_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ct, ct_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ht, ht_r, rtol=1e-5, atol=1e-6)


def _q8_ste_loss(w, b, xp):
    return _loss(lambda w, b, x: ref.lstm_seq(
        ref.quantize_dequantize_ste(w), b, x))(w, b, xp)


@pytest.mark.parametrize("shape", [
    (2, 32, 9, 3, 7), (1, 8, 5, 2, 1), (3, 16, 40, 5, 4),
], ids=["odd", "T1", "DgtH"])
def test_q8_bwd_matches_ste_oracle_grads(shape):
    """The q8 reverse sweep reproduces the straight-through reference
    gradients (grad through the dequantized weights, identity to the
    masters) on the degenerate shapes."""
    w, b, xp, _ = _make(*shape)
    gk = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq_q8(
        w, b, x, bwd_block_b=2)), argnums=(0, 1, 2))(w, b, xp)
    gr = jax.grad(_q8_ste_loss, argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(gk, gr):
        assert bool(jnp.all(jnp.isfinite(a)))
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-5)
    assert all(float(jnp.max(jnp.abs(g))) > 0 for g in gk)


def test_q8_bwd_batch_tiling_invariance():
    """Non-dividing batch tiles (masked shared dw/db accumulators) under
    the q8 sweep still match the STE reference."""
    w, b, xp, _ = _make(2, 24, 9, 5, 6)
    gr = jax.grad(_q8_ste_loss, argnums=(0, 1, 2))(w, b, xp)
    for block_b in (1, 2, 3, 5, 8):
        gk = jax.grad(_loss(lambda w, b, x, bb=block_b: lstm_seq.lstm_seq_q8(
            w, b, x, bwd_block_b=bb)), argnums=(0, 1, 2))(w, b, xp)
        for a, r in zip(gk, gr):
            np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("tc", [1, 2, 3, 7, 16])
def test_q8_chunked_forward_bit_identical(tc):
    """Time streaming composes with int8 weights: chunked and unchunked q8
    kernels are bit-identical (chunking changes data movement only, for
    every weight dtype)."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    want = lstm_seq.lstm_seq_q8(w, b, xp, block_b=2)
    got = lstm_seq.lstm_seq_q8(w, b, xp, block_b=2, time_chunk=tc)
    for a, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


@pytest.mark.parametrize("tc", [1, 3, 7])
def test_q8_chunked_grads_bit_identical(tc):
    """The streamed q8 reverse sweep leaves gradients EXACTLY equal to the
    unchunked q8 sweep's — including the folded-scale gate recompute across
    chunk boundaries."""
    w, b, xp, _ = _make(2, 16, 9, 3, 7)
    g_res = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq_q8(
        w, b, x, bwd_block_b=2)), argnums=(0, 1, 2))(w, b, xp)
    g_chn = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq_q8(
        w, b, x, bwd_block_b=2, bwd_time_chunk=tc)),
        argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(g_chn, g_res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_q8_oracle_bwd_fallback_matches_kernel():
    """bwd_block_b=ORACLE_BWD on the q8 path drops to the dequantize-oracle
    VJP — same straight-through grads as the fused q8 sweep."""
    w, b, xp, _ = _make(2, 16, 9, 3, 5)
    g_forced = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq_q8(
        w, b, x, bwd_block_b=lstm_seq.ORACLE_BWD)),
        argnums=(0, 1, 2))(w, b, xp)
    g_kernel = jax.grad(_loss(lambda w, b, x: lstm_seq.lstm_seq_q8(
        w, b, x, bwd_block_b=2)), argnums=(0, 1, 2))(w, b, xp)
    for a, r in zip(g_forced, g_kernel):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-5)


def test_q8_choose_batch_block_widens_budget():
    """The quantization-aware budget math, pure: with 1-byte weights the
    table admits a (block_b, time_chunk) at budgets where f32 weights
    return finer tiles or nothing at all."""
    # (a) budget below the f32 weight-stack floor but above the int8 one:
    # f32 not viable at all, q8 viable
    f32_floor = lstm_seq.working_set_bytes(128, 2, 32, 32, 1, mode="fwd",
                                           time_chunk=1)
    q8_floor = lstm_seq.working_set_bytes(128, 2, 32, 32, 1, mode="fwd",
                                          time_chunk=1, quantized=True)
    assert q8_floor < f32_floor
    budget = f32_floor - 1
    assert lstm_seq.choose_batch_block(8, 128, 2, 32, 32,
                                       vmem_budget=budget) is None
    q8 = lstm_seq.choose_batch_block(8, 128, 2, 32, 32, vmem_budget=budget,
                                     quantized=True)
    assert q8 is not None
    # (b) budget where f32 must stream but q8 keeps whole-T residency
    ws_f32 = lstm_seq.working_set_bytes(128, 2, 32, 32, 8)
    ws_q8 = lstm_seq.working_set_bytes(128, 2, 32, 32, 8, quantized=True)
    assert ws_q8 < ws_f32
    mid = ws_f32 - 1
    f32_mid = lstm_seq.choose_batch_block(8, 128, 2, 32, 32, vmem_budget=mid)
    q8_mid = lstm_seq.choose_batch_block(8, 128, 2, 32, 32, vmem_budget=mid,
                                         quantized=True)
    assert f32_mid is not None and f32_mid.time_chunk is not None
    assert q8_mid == lstm_seq.SeqBlocks(8, None)
    # (c) bwd floors: the f32 dw/db outs of the q8 plan cost MORE than int8
    # outs would, yet the quartered weight stack still nets a lower floor
    f32_bwd = lstm_seq.working_set_bytes(16, 2, 32, 32, 1, mode="bwd",
                                         time_chunk=1)
    q8_bwd = lstm_seq.working_set_bytes(16, 2, 32, 32, 1, mode="bwd",
                                        time_chunk=1, quantized=True)
    assert q8_bwd < f32_bwd
