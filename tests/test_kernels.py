"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,D,H", [(1, 9, 32), (6, 9, 40), (8, 32, 64),
                                   (3, 128, 128), (5, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_sweep(B, D, H, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * H), 5)
    w = (jax.random.normal(ks[0], (D + H, 4 * H)) * 0.2).astype(dtype)
    b = (jax.random.normal(ks[1], (4 * H,)) * 0.1).astype(dtype)
    x = jax.random.normal(ks[2], (B, D)).astype(dtype)
    c = jax.random.normal(ks[3], (B, H)).astype(dtype)
    h = jax.random.normal(ks[4], (B, H)).astype(dtype)
    c1, h1 = ops.lstm_cell(w, b, x, c, h)
    c2, h2 = ref.lstm_cell(w, b, x, c, h)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("block_b,block_h", [(2, 16), (128, 128), (3, 8)])
def test_lstm_cell_block_invariance(block_b, block_h):
    """MobiRNN's point: factorization changes performance, never results."""
    B, D, H = 5, 9, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w = jax.random.normal(ks[0], (D + H, 4 * H)) * 0.2
    b = jax.random.normal(ks[1], (4 * H,)) * 0.1
    x, c, h = (jax.random.normal(k, (B, dim)) for k, dim in
               zip(ks[2:], (D, H, H)))
    c1, h1 = ops.lstm_cell(w, b, x, c, h, block_b=block_b, block_h=block_h)
    c2, h2 = ref.lstm_cell(w, b, x, c, h)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,dk,dv,chunk", [(32, 8, 8, 8), (64, 16, 16, 16),
                                           (64, 64, 64, 32), (16, 4, 8, 4)])
def test_wkv6_sweep(T, dk, dv, chunk):
    BH = 3
    ks = jax.random.split(jax.random.PRNGKey(T + dk), 6)
    r = jax.random.normal(ks[0], (BH, T, dk))
    k = jax.random.normal(ks[1], (BH, T, dk))
    v = jax.random.normal(ks[2], (BH, T, dv))
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, T, dk)))
    u = jax.random.normal(ks[4], (BH, dk))
    s0 = jax.random.normal(ks[5], (BH, dk, dv))
    o1, s1 = ops.wkv6(r, k, v, logw, u, s0, chunk=chunk)
    for i in range(BH):
        o2, s2 = ref.wkv6_stepwise(r[i], k[i], v[i], logw[i], u[i], s0[i])
        np.testing.assert_allclose(o1[i], o2, rtol=4e-4, atol=4e-4)
        np.testing.assert_allclose(s1[i], s2, rtol=4e-4, atol=4e-4)


def test_wkv6_strong_decay_stability():
    """log-decay near the clamp floor must not overflow (the chunked form
    only ever exponentiates non-positive numbers)."""
    BH, T, dk = 2, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    r = jax.random.normal(ks[0], (BH, T, dk))
    k = jax.random.normal(ks[1], (BH, T, dk))
    v = jax.random.normal(ks[2], (BH, T, dk))
    logw = jnp.full((BH, T, dk), -12.0)       # extremely strong decay
    u = jax.random.normal(ks[3], (BH, dk))
    s0 = jnp.zeros((BH, dk, dk))
    o, s = ops.wkv6(r, k, v, logw, u, s0, chunk=16)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(s)))


@pytest.mark.parametrize("B,Hq,Hkv,S,dh,block", [
    (2, 8, 2, 96, 32, 32), (1, 4, 4, 64, 64, 64), (3, 16, 2, 128, 16, 128),
    (2, 2, 1, 33, 8, 16),
])
def test_decode_attn_sweep(B, Hq, Hkv, S, dh, block):
    ks = jax.random.split(jax.random.PRNGKey(S + Hq), 3)
    q = jax.random.normal(ks[0], (B, Hq, dh))
    kc = jax.random.normal(ks[1], (B, S, Hkv, dh))
    vc = jax.random.normal(ks[2], (B, S, Hkv, dh))
    lens = jnp.arange(1, B + 1) * (S // (B + 1)) + 1
    o1 = ops.decode_attn(q, kc, vc, lens.astype(jnp.int32), block_s=block)
    o2 = ref.decode_attn(q, kc, vc, lens)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_wkv6_chunked_ref_equals_stepwise():
    """The chunked (coarse) jnp formulation == per-step (fine) recurrence —
    MobiRNN's invariant that work-unit coarsening preserves results."""
    T, dk = 48, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    r, k, v = (jax.random.normal(ks[i], (T, dk)) for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (T, dk)))
    u = jax.random.normal(ks[4], (dk,))
    s0 = jax.random.normal(ks[5], (dk, dk))
    for chunk in (1, 4, 12, 48):
        o1, s1 = ref.wkv6(r, k, v, logw, u, s0, chunk=chunk)
        o2, s2 = ref.wkv6_stepwise(r, k, v, logw, u, s0)
        np.testing.assert_allclose(o1, o2, rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=3e-4)
