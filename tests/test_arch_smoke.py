"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward and one train step on CPU; output shapes and
finiteness are asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro import steps
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import registry
from repro.optim import AdamW
from repro.partitioning import split

# multi-second integration sweeps: excluded from the quick loop (-m "not slow")
pytestmark = pytest.mark.slow

SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = ARCHS[name].reduced()
            m = registry.build(cfg)
            params, _ = split(m.init(jax.random.PRNGKey(0)))
            batch = registry.make_batch(cfg, SHAPE, jax.random.PRNGKey(1))
            cache[name] = (cfg, m, params, batch)
        return cache[name]

    return get


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_constraints(name):
    cfg = ARCHS[name].reduced()
    assert cfg.d_model <= 512
    assert cfg.n_layers <= 8
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_shapes_and_finite(built, name):
    cfg, m, params, batch = built(name)
    logits, aux = m.forward(params, batch)
    B, S = 2, 32
    if cfg.n_codebooks:
        assert logits.shape == (B, cfg.n_codebooks, S, cfg.vocab)
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_one_train_step(built, name):
    cfg, m, params, batch = built(name)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    new_params, state, metrics = steps.train_step(opt, cfg, params, state,
                                                  batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_one_token(built, name):
    cfg, m, params, batch = built(name)
    cache, _ = split(m.init_cache(2, 16))
    tok = (batch["tokens"][:, :, 0] if cfg.n_codebooks
           else batch["tokens"][:, 0])
    logits, cache2 = m.decode_step(params, cache, {"tokens": tok})
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["pos"]) == 1
