"""Tests on the family-generic tiling substrate (core/tiling.py): the
working-set-term algebra, the fwd/bwd mode split, and the coarseness-
ordered joint (batch_tile, time_chunk) search — plus the delegation
contract: all three family choosers (kernels/lstm_seq.choose_batch_block,
kernels/wkv6.choose_blocks, kernels/mamba_scan.choose_blocks) are thin
``fits`` closures over the ONE shared search, so its priority order is
their priority order."""
import pytest

from repro.core import tiling


# ---------------------------------------------------------------------------
# residency helpers
# ---------------------------------------------------------------------------
def test_check_mode():
    assert tiling.check_mode("fwd") == "fwd"
    assert tiling.check_mode("bwd") == "bwd"
    with pytest.raises(ValueError, match="mode"):
        tiling.check_mode("train")


def test_weight_dtype_bytes_precedence():
    # explicit override wins over everything
    assert tiling.weight_dtype_bytes(4, w_dtype_bytes=2) == 2
    assert tiling.weight_dtype_bytes(4, w_dtype_bytes=2, quantized=True) == 2
    # quantized plans hold int8 weights
    assert tiling.weight_dtype_bytes(4, quantized=True) == 1
    # float plans hold activation-width weights
    assert tiling.weight_dtype_bytes(4) == 4
    assert tiling.weight_dtype_bytes(2) == 2


def test_streamed_rows():
    assert tiling.streamed_rows(64, None) == 64          # whole-axis
    assert tiling.streamed_rows(64, 8) == 2 * 8          # double-buffered
    assert tiling.streamed_rows(64, 128) == 2 * 64       # clamped to T
    assert tiling.streamed_rows(64, 8, slots=3) == 24


def test_bwd_window_rows_overlap():
    assert tiling.bwd_window_rows(64, 8) == 9    # one overlap row
    assert tiling.bwd_window_rows(64, 64) == 64  # single chunk: no overlap
    assert tiling.bwd_window_rows(64, 128) == 64  # clamp first


def test_chunk_grid_arithmetic():
    assert tiling.ceil_chunks(64, 8) == 8
    assert tiling.ceil_chunks(61, 8) == 8        # non-dividing tail
    assert tiling.ceil_chunks(64, 128) == 1      # clamp
    assert tiling.streamed_axis_rows(64, None) == 64
    assert tiling.streamed_axis_rows(61, 8) == 64   # tail priced in full
    assert tiling.pad_tiles(5, 2) == 6
    assert tiling.pad_tiles(4, 2) == 4


# ---------------------------------------------------------------------------
# WorkingSet: the named-term algebra and the fwd/bwd split
# ---------------------------------------------------------------------------
def test_working_set_mode_split():
    fwd = (tiling.WorkingSet("fwd").add("x", 100)
           .add("traj", 900, bwd_only=True))
    bwd = (tiling.WorkingSet("bwd").add("x", 100)
           .add("traj", 900, bwd_only=True))
    assert fwd.total() == 100 and "traj" not in fwd.terms
    assert bwd.total() == 1000 and bwd.terms["traj"] == 900
    with pytest.raises(ValueError, match="mode"):
        tiling.WorkingSet("train")


def test_working_set_accumulates_by_name():
    ws = tiling.WorkingSet().add("x", 10).add("x", 5)
    assert ws.terms == {"x": 15} and ws.total() == 15


def test_halving_walk():
    assert list(tiling.halving(32)) == [32, 16, 8, 4, 2, 1]
    assert list(tiling.halving(3)) == [3, 1]
    assert list(tiling.halving(1)) == [1]
    assert list(tiling.halving(32, floor=8)) == [32, 16, 8]


# ---------------------------------------------------------------------------
# joint_search: MobiRNN coarseness order
# ---------------------------------------------------------------------------
def test_joint_search_prefers_whole_t_at_coarsest_tile():
    calls = []

    def fits(bm, tc):
        calls.append((bm, tc))
        return True

    assert tiling.joint_search(8, 64, fits) == (8, None)
    assert calls == [(8, None)]          # nothing finer was even probed


def test_joint_search_streams_before_shrinking_batch():
    # whole-T never fits, tc=16 fits at the full batch tile: the search
    # must stream time at the coarse tile, NOT halve the batch tile
    def fits(bm, tc):
        return tc is not None and tc <= 16
    assert tiling.joint_search(8, 64, fits) == (8, 32 // 2)


def test_joint_search_halves_batch_last():
    # only (batch_tile <= 2, tc <= 4) fits: chunk sweep must be exhausted
    # at each batch tile before the tile halves
    calls = []

    def fits(bm, tc):
        calls.append((bm, tc))
        return bm <= 2 and tc is not None and tc <= 4
    assert tiling.joint_search(8, 64, fits) == (2, 4)
    # every chunk candidate at bm=8 ran before any bm=4 candidate
    assert calls.index((4, None)) > calls.index((8, 1))


def test_joint_search_exhaustion_and_flags():
    assert tiling.joint_search(8, 64, lambda bm, tc: False) is None
    # allow_chunk=False: whole-axis residency or bust
    assert tiling.joint_search(
        8, 64, lambda bm, tc: tc is not None, allow_chunk=False) is None
    # whole_t_first=False (always-chunked kernels): tc=None never probed
    def fits(bm, tc):
        assert tc is not None
        return True
    assert tiling.joint_search(
        8, 64, fits, whole_t_first=False, chunk_start=16) == (8, 16)
    # seed_batch_tile clamps into [1, batch]
    assert tiling.joint_search(
        4, 64, lambda bm, tc: tc is None, seed_batch_tile=99) == (4, None)


# ---------------------------------------------------------------------------
# delegation: the three family choosers ride the one search
# ---------------------------------------------------------------------------
def test_lstm_chooser_delegates_to_joint_search():
    from repro.kernels import lstm_seq

    shape = dict(seq_len=256, n_layers=2, p_width=40, hidden=64)
    blocks = lstm_seq.choose_batch_block(32, **shape)
    assert blocks is not None

    def fits(bm, tc):
        return lstm_seq.working_set_bytes(
            shape["seq_len"], shape["n_layers"], shape["p_width"],
            shape["hidden"], bm,
            time_chunk=tc) <= lstm_seq.factorization.DEFAULT_VMEM_BUDGET

    got = tiling.joint_search(32, shape["seq_len"], fits,
                              seed_batch_tile=blocks.block_b)
    assert got == tuple(blocks)


def test_wkv6_chooser_is_always_chunked():
    from repro.kernels import wkv6

    blocks = wkv6.choose_blocks(8, 128, 64, 64, target=32)
    assert blocks == wkv6.WkvBlocks(32, 8)    # coarsest point, never None-tc
    # pressure refines (coarseness order: chunk halves before bh tile)
    ws = wkv6.working_set_bytes(128, 64, 64, 32, bh_tile=8)
    tight = wkv6.choose_blocks(8, 128, 64, 64, target=32, vmem_budget=ws - 1)
    assert tight is not None and tuple(tight) != tuple(blocks)


def test_mamba_chooser_whole_t_first():
    from repro.kernels import mamba_scan

    blocks = mamba_scan.choose_blocks(4, 64, 16, 8)
    assert blocks == mamba_scan.MambaBlocks(4, 64)   # whole-T residency
    assert mamba_scan.choose_blocks(
        4, 4096, 4096, 64, vmem_budget=4096) is None
