"""Roofline accounting: analytic param counts vs real trees; HLO collective
parser on synthetic HLO."""
import jax
import pytest

from repro import analysis
from repro.configs import ARCHS, INPUT_SHAPES
from repro.models import registry
from repro.partitioning import param_count, split


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_analytic_param_count_matches_real_tree(name):
    """The analytic formula must agree with the materialised reduced model
    (within 2% — norms/scalars accounting tolerance)."""
    cfg = ARCHS[name].reduced()
    m = registry.build(cfg)
    params, _ = split(m.init(jax.random.PRNGKey(0)))
    real = param_count(params)
    approx, active = analysis.param_counts(cfg)
    assert abs(approx - real) / real < 0.02, (approx, real)
    assert active <= approx


def test_active_params_below_total_for_moe():
    total, active = analysis.param_counts(ARCHS["qwen3-moe-30b-a3b"])
    assert active < total / 4      # 8 of 128 experts per token


def test_full_scale_param_counts_sane():
    """Sanity against the published model sizes (within ~20%)."""
    expect = {"yi-9b": 8.8e9, "command-r-35b": 35e9, "qwen2-0.5b": 0.5e9,
              "olmoe-1b-7b": 6.9e9, "qwen3-moe-30b-a3b": 30e9,
              "rwkv6-3b": 3.1e9, "jamba-1.5-large-398b": 398e9,
              "stablelm-12b": 12e9, "musicgen-large": 3.3e9}
    for name, target in expect.items():
        total, _ = analysis.param_counts(ARCHS[name])
        assert 0.7 < total / target < 1.45, (name, total, target)


def test_model_flops_scaling():
    cfg = ARCHS["yi-9b"]
    tr = analysis.model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = analysis.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = analysis.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(3 * pf, rel=1e-6)    # 6N vs 2N, same tokens
    assert dc < pf / 1000                           # one token vs 32k


def test_analytic_costs_decode_memory_dominated():
    """Decode at 32k context must be memory-bound (cache streaming) for a
    dense arch — the classic serving roofline."""
    cfg = ARCHS["yi-9b"]
    costs = analysis.analytic_costs(cfg, INPUT_SHAPES["decode_32k"])
    t_comp = costs["flops"] / (256 * analysis.PEAK_FLOPS)
    t_mem = costs["bytes"] / (256 * analysis.HBM_BW)
    assert t_mem > t_comp


SAMPLE_HLO = """
%add.1 (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}
%body.2 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), channel_id=1, to_apply=%add.1
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}
%cond.3 (p: (s32[], f32[128,256])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}
ENTRY %main.4 (p0: f32[128,256]) -> f32[128,256] {
  %ag = bf16[64,512]{1,0} all-gather(%p0), channel_id=2
  %w = (s32[], f32[128,256]) while(%init), condition=%cond.3, body=%body.2
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_scales_by_trip_count():
    coll = analysis.collective_bytes(SAMPLE_HLO)
    assert coll["all-reduce"] == 24 * 128 * 256 * 4
    assert coll["all-gather"] == 64 * 512 * 2


def test_roofline_dominant_term():
    r = analysis.Roofline(flops=1e18, hbm_bytes=1e9, coll_bytes={},
                          n_chips=256, model_flops=5e17)
    assert r.dominant == "compute"
    assert 0.4 < r.useful_flops_frac < 0.6
    r2 = analysis.Roofline(flops=1e12, hbm_bytes=1e15, coll_bytes={},
                           n_chips=256, model_flops=1e12)
    assert r2.dominant == "memory"


def test_lstm_seq_stream_costs_quantized_weight_term():
    """The quantization-aware roofline: int8 weights cut the streamed
    weight traffic ~4x per batch tile (scales/f32-bias ride along), never
    touch the activation/trajectory terms, and the bwd write-out stays f32
    (straight-through master grads)."""
    kw = dict(seq_len=128, n_layers=2, p_width=32, hidden=32, batch=8,
              block_b=2, time_chunk=16)
    f32 = analysis.lstm_seq_stream_costs(**kw)
    q8 = analysis.lstm_seq_stream_costs(**kw, quantized=True)
    w_count = 2 * (32 + 32) * 4 * 32
    b_count = 2 * 4 * 32
    # per-tile weight traffic: f32 stack vs int8 stack + f32 bias + scales
    delta_per_tile = (w_count + b_count) * 4 - (w_count + b_count * 8)
    n_tiles = 8 // 2
    assert f32["hbm_bytes"] - q8["hbm_bytes"] == n_tiles * delta_per_tile
    assert f32["flops"] == q8["flops"]          # same MXU work
    # bwd: identical dw/db write-out (f32 either way), same per-tile delta
    f32b = analysis.lstm_seq_stream_costs(**kw, mode="bwd")
    q8b = analysis.lstm_seq_stream_costs(**kw, mode="bwd", quantized=True)
    assert f32b["hbm_bytes"] - q8b["hbm_bytes"] == n_tiles * delta_per_tile
    # resident side matches the kernel budget model
    from repro.kernels import lstm_seq as seq_lib
    assert q8["vmem_resident_bytes"] == seq_lib.working_set_bytes(
        128, 2, 32, 32, 2, time_chunk=16, quantized=True)
