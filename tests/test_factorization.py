"""Work-unit cost model (paper Figs 2-3) and the Pallas block chooser."""
import pytest

from repro.core import factorization as fz


def test_fine_grained_slower_on_mobile_gpu():
    """The paper's central measurement: per-column factorization (Fig 2b)
    on the constrained GPU is SLOWER than single-threaded CPU; the packed
    factorization (Fig 2c) is faster."""
    in_dim, out = 32, 120
    t_fine_gpu = fz.factorize_gate(fz.MOBILE_GPU, in_dim, out, 1)
    t_cpu = fz.factorize_gate(fz.MOBILE_CPU1, in_dim, out, out)
    best = fz.best_cols_per_unit(fz.MOBILE_GPU, in_dim, out)
    t_packed_gpu = fz.factorize_gate(fz.MOBILE_GPU, in_dim, out, best)
    assert t_fine_gpu > t_cpu, "fine-grained offload must lose (Fig 3)"
    assert t_packed_gpu < t_fine_gpu, "packing must win (Fig 2c)"


def test_desktop_gpu_tolerates_fine_grain():
    """On the desktop profile the same fine factorization is fine — that is
    why the CUDA recipe exists in the first place."""
    in_dim, out = 32, 120
    t_fine_desktop = fz.factorize_gate(fz.DESKTOP_GPU, in_dim, out, 1)
    t_cpu = fz.factorize_gate(fz.MOBILE_CPU1, in_dim, out, out)
    assert t_fine_desktop < t_cpu


def test_unit_time_monotone_in_units():
    f = 2.0 * 32
    t1 = fz.unit_time(fz.MOBILE_GPU, 1, f)
    t120 = fz.unit_time(fz.MOBILE_GPU, 120, f)
    assert t120 >= t1


def test_choose_block_alignment_and_budget():
    bm, bn, bk = fz.choose_block(4096, 11008, 4096)
    for b in (bm, bn, bk):
        assert b % fz.MXU_ALIGN == 0
    ws = 2 * (bm * bk + bk * bn) + 4 * bm * bn
    assert ws <= fz.DEFAULT_VMEM_BUDGET


def test_choose_block_prefers_coarse():
    """Small problems -> one block (the coarsest factorization that fits)."""
    bm, bn, bk = fz.choose_block(128, 128, 128)
    assert fz.grid_steps(128, 128, 128, (bm, bn, bk)) == 1


def test_choose_block_shrinks_under_tiny_budget():
    bm, bn, bk = fz.choose_block(4096, 4096, 4096,
                                 vmem_budget=1 << 20)
    ws = 2 * (bm * bk + bk * bn) + 4 * bm * bn
    assert ws <= 1 << 20 or (bm == bn == bk == fz.MXU_ALIGN)
