"""Logical-axis partitioning with divisibility fallback.

The framework annotates every parameter / state tensor with *logical* axis
names (e.g. ``('layers', 'embed', 'mlp')``).  A rule table maps logical names
to mesh axes.  At sharding time each rule is validated against the actual
dimension size: a rule whose dimension is not divisible by the mesh axis size
is dropped (the dim stays replicated).  This is the TPU analogue of MobiRNN's
device-shape-aware factorization: the same model gets a different, valid
decomposition on every device mesh without per-model hand tuning.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def shard_map(f: Callable, mesh: Mesh, in_specs: Any, out_specs: Any
              ) -> Callable:
    """Version-compat shard_map with replication checking disabled.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=)``; this container's
    jax still has ``jax.experimental.shard_map.shard_map(..., check_rep=)``.
    All framework call sites (models/moe.py, models/rwkv.py) go through
    here so the suite runs on both.
    """
    if hasattr(jax, "shard_map"):
        fn, kw = jax.shard_map, "check_vma"
    else:
        from jax.experimental.shard_map import shard_map as fn
        kw = "check_rep"
    # the top-level promotion predates the check_rep->check_vma rename, so
    # probe the signature instead of trusting the import location
    import inspect
    try:
        if kw not in inspect.signature(fn).parameters:
            kw = "check_rep" if kw == "check_vma" else "check_vma"
    except (TypeError, ValueError):   # signature unavailable: keep default
        pass
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: False})

# ---------------------------------------------------------------------------
# Logical axis names used throughout the framework.
# ---------------------------------------------------------------------------
#   batch     global batch dimension of activations
#   seq       sequence dimension of activations / caches
#   cache_seq sequence dimension of decode KV caches (shardable on model axis)
#   embed     d_model dimension of weights (FSDP axis)
#   mlp       hidden/ffn output dimension of weights (tensor-parallel axis)
#   heads     query-head dimension (tensor-parallel axis)
#   kv_heads  kv-head dimension (tensor-parallel axis)
#   experts   MoE expert dimension (expert-parallel axis)
#   vocab     vocabulary dimension (tensor-parallel axis)
#   layers    stacked-layer leading dim of scanned params (never sharded)
#   state     recurrent state channels (tensor-parallel when divisible)

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "seq_model": ("model",),     # sequence parallelism (cfg.seq_shard)
    "cache_seq": ("model",),
    "embed": ("data",),          # FSDP-style weight sharding over data axis
    "embed_nofsdp": (),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "layers": (),
    "state": ("model",),
    None: (),
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """A rule table bound to a mesh; resolves logical names -> PartitionSpec."""

    rules: Mapping[str, tuple[str, ...]]
    mesh: Mesh

    def mesh_axis_size(self, names: tuple[str, ...]) -> int:
        size = 1
        for n in names:
            size *= self.mesh.shape.get(n, 1)
        return size

    def spec_for(self, logical_axes: Sequence[str | None], shape: Sequence[int]
                 ) -> PartitionSpec:
        if len(logical_axes) != len(shape):
            raise ValueError(
                f"logical axes {logical_axes} rank != shape {shape} rank")
        used: set[str] = set()
        parts: list[Any] = []
        for name, dim in zip(logical_axes, shape):
            mesh_axes = tuple(a for a in self.rules.get(name, ())
                              if a in self.mesh.shape and a not in used)
            if not mesh_axes:
                parts.append(None)
                continue
            # divisibility fallback: drop trailing mesh axes until divisible
            while mesh_axes and dim % self.mesh_axis_size(mesh_axes) != 0:
                mesh_axes = mesh_axes[:-1]
            if not mesh_axes:
                parts.append(None)
                continue
            used.update(mesh_axes)
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        # strip trailing Nones for a tidy spec
        while parts and parts[-1] is None:
            parts.pop()
        return PartitionSpec(*parts)

    def sharding_for(self, logical_axes: Sequence[str | None],
                     shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, shape))


def make_rules(mesh: Mesh, overrides: Mapping[str, tuple[str, ...]] | None = None
               ) -> AxisRules:
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    return AxisRules(rules=rules, mesh=mesh)


# ---------------------------------------------------------------------------
# Activation-sharding context.
#
# Model code calls ``constrain(x, logical_axes)`` at layer boundaries; under
# a ``use_rules(rules)`` context (set by the dry-run / training / serving
# drivers) this lowers to ``with_sharding_constraint`` so XLA keeps
# activations batch-sharded instead of back-propagating weight layouts into
# them.  Outside the context it is a no-op (single-device tests).
# ---------------------------------------------------------------------------
_ACTIVE_RULES: list[AxisRules] = []


class use_rules:
    def __init__(self, rules: AxisRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()


def constrain(x: Any, logical_axes: Sequence[str | None]) -> Any:
    if not _ACTIVE_RULES:
        return x
    rules = _ACTIVE_RULES[-1]
    return jax.lax.with_sharding_constraint(
        x, rules.sharding_for(logical_axes, x.shape))


# ---------------------------------------------------------------------------
# Annotated parameter trees.
#
# Model init functions build a pytree whose leaves are ``Annot`` records —
# an array (or ShapeDtypeStruct) plus its logical axes.  ``split`` separates
# the value tree from the axes tree; ``tree_specs`` turns an axes tree +
# value tree into a PartitionSpec tree.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Annot:
    value: Any                       # jnp array or jax.ShapeDtypeStruct
    axes: tuple[str | None, ...]     # logical axis names, one per dim

    def __post_init__(self):
        shape = getattr(self.value, "shape", None)
        if shape is not None and isinstance(self.axes, tuple) \
                and len(self.axes) != len(shape):
            raise ValueError(
                f"axes {self.axes} rank mismatch with shape {shape}")


# Registered as a pytree node (axes are static metadata) so Annot trees pass
# through jax transforms — in particular jax.eval_shape for abstract init.
jax.tree_util.register_pytree_node(
    Annot,
    lambda a: ((a.value,), a.axes),
    lambda axes, children: Annot(children[0], axes),
)


def is_annot(x: Any) -> bool:
    return isinstance(x, Annot)


def split(tree: Any) -> tuple[Any, Any]:
    """Split an Annot tree into (values, axes) trees of identical structure."""
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annot)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annot)
    return values, axes


def tree_specs(axes_tree: Any, value_tree: Any, rules: AxisRules) -> Any:
    """PartitionSpec tree from an axes tree and matching value tree."""
    return jax.tree.map(
        lambda ax, v: rules.spec_for(ax, v.shape),
        axes_tree, value_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree: Any, value_tree: Any, rules: AxisRules) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s),
        tree_specs(axes_tree, value_tree, rules),
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def stack_axes(axes: tuple[str | None, ...]) -> tuple[str | None, ...]:
    """Axes tuple for a param stacked over layers (scan-over-layers)."""
    return ("layers",) + tuple(axes)


def param_count(params: Any) -> int:
    return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))


def bytes_of(tree: Any) -> int:
    return int(sum(np.prod(p.shape) * jax.dtypes.canonicalize_dtype(p.dtype).itemsize
                   for p in jax.tree.leaves(tree)))


# Convenience initializers ---------------------------------------------------
def trunc_normal(key: jax.Array, shape: Sequence[int], scale: float,
                 dtype: Any) -> jax.Array:
    import jax.numpy as jnp
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key: jax.Array, shape: Sequence[int], axes: tuple,
               dtype: Any, scale: float | None = None) -> Annot:
    """Fan-in scaled truncated-normal init, annotated."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return Annot(trunc_normal(key, shape, s, dtype), axes)


def zeros_init(shape: Sequence[int], axes: tuple, dtype: Any) -> Annot:
    import jax.numpy as jnp
    return Annot(jnp.zeros(shape, dtype), axes)


def ones_init(shape: Sequence[int], axes: tuple, dtype: Any) -> Annot:
    import jax.numpy as jnp
    return Annot(jnp.ones(shape, dtype), axes)
