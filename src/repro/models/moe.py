"""Top-k mixture-of-experts with capacity-based dense dispatch.

Dispatch algorithm (sort-free, SPMD-friendly — no ragged shapes):
  1. router: softmax(x @ Wr) -> top-k (expert ids, weights) per token
  2. position-in-expert via masked cumsum over the flattened (token, k) slots
  3. scatter token vectors into a preallocated (E, C, D) expert buffer
     (C = capacity; slots beyond capacity are DROPPED, standard GShard rule)
  4. batched expert matmuls (E, C, D) x (E, D, F) — experts shard over the
     'model' mesh axis (expert parallelism; XLA inserts the all-to-all class
     collectives for the scatter/gather across expert shards)
  5. gather back and combine with router weights

The capacity factor is the MoE instance of MobiRNN's work-unit coarsening:
it trades wasted padding slots (coarse, uniform work units the accelerator
likes) against token drops — benchmarked in the perf log.

Aux losses: switch-style load-balance loss + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.partitioning import Annot, constrain, shard_map


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    moe = cfg.moe
    d, e, ff = cfg.d_model, moe.n_experts, moe.d_ff
    ks = jax.random.split(key, 4)

    def w(k, shape, axes, scale):
        return Annot((jax.random.truncated_normal(k, -2.0, 2.0, shape,
                                                  jnp.float32) * scale
                      ).astype(dtype), axes)

    p = {
        # router is tiny and every shard routes locally: keep it replicated
        "router": w(ks[0], (d, e), ("embed_nofsdp", None), d ** -0.5),
        "wd": w(ks[3], (e, ff, d), ("experts", "mlp", None), ff ** -0.5),
    }
    if cfg.mlp_act == "swiglu":
        p["wg"] = w(ks[1], (e, d, ff), ("experts", None, "mlp"), d ** -0.5)
        p["wu"] = w(ks[2], (e, d, ff), ("experts", None, "mlp"), d ** -0.5)
    else:
        p["wi"] = w(ks[1], (e, d, ff), ("experts", None, "mlp"), d ** -0.5)
    return p


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = int(moe.capacity_factor * n_tokens * moe.top_k / moe.n_experts)
    return max(c, moe.top_k)


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig, *,
              no_drop: bool = False) -> tuple[jax.Array, dict]:
    """x: (..., d) -> (same shape, aux dict with load-balance losses).

    no_drop=True sets capacity to T (a token appears at most once per
    expert), guaranteeing zero drops — used by the inference paths so that
    decode == forward exactly; training keeps the capacity-factor bound
    (GShard rule).

    Under an active sharding-rules context with a >1 'model' mesh axis the
    expert-parallel shard_map path is used (see _apply_moe_ep); otherwise
    the single-device dense-dispatch path below runs.
    """
    from repro import partitioning as pt

    if pt._ACTIVE_RULES:
        rules = pt._ACTIVE_RULES[-1]
        m = rules.mesh.shape.get("model", 1)
        if m > 1 and cfg.moe.n_experts % m == 0 and x.ndim == 3:
            return _apply_moe_ep(p, x, cfg, rules, no_drop)
    return _apply_moe_dense(p, x, cfg, no_drop)


def _apply_moe_dense(p: dict, x: jax.Array, cfg: ModelConfig,
                     no_drop: bool) -> tuple[jax.Array, dict]:
    moe = cfg.moe
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    E, K = moe.n_experts, moe.top_k
    C = T if no_drop else capacity(T, cfg)

    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalise

    # --- position-in-expert over flattened (T*K,) slots ------------------
    flat_e = top_e.reshape(-1)                               # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot           # before me
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                # (T*K,)
    keep = pos < C
    dst_e = jnp.where(keep, flat_e, E)                       # drop -> row E
    dst_c = jnp.where(keep, pos, 0)

    # --- scatter to (E+1, C, D); row E is the drop bin -------------------
    xk = jnp.repeat(xt, K, axis=0)                           # (T*K, D)
    buf = jnp.zeros((E + 1, C, d), xt.dtype)
    buf = buf.at[dst_e, dst_c].set(xk, mode="drop")
    expert_in = constrain(buf[:E], ("experts", None, None))  # (E, C, D)

    # --- expert computation (batched over experts) -----------------------
    if "wg" in p:
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["wg"]))
             * jnp.einsum("ecd,edf->ecf", expert_in, p["wu"]))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", expert_in, p["wi"]),
                        approximate=True)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wd"])      # (E, C, D)

    # --- gather back and combine -----------------------------------------
    out_k = expert_out[dst_e % E, dst_c]                     # (T*K, D)
    out_k = out_k * (keep[:, None].astype(out_k.dtype))
    out_k = out_k * top_p.reshape(-1)[:, None].astype(out_k.dtype)
    out = jnp.sum(out_k.reshape(T, K, d), axis=1)
    if len(orig_shape) == 3:
        out = constrain(out.reshape(orig_shape), ("batch", "seq", None)
                        ).reshape(T, d)

    # --- aux losses (switch-transformer style) ----------------------------
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    load_balance = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"moe_load_balance": load_balance, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return out.reshape(orig_shape).astype(x.dtype), aux


@jax.custom_jvp
def _dtype_pin(x):
    """optimization_barrier with an identity differentiation rule — the
    barrier is a scheduling hint, so its tangent/cotangent pass straight
    through (jax < 0.5 defines no rule for the raw primitive)."""
    return jax.lax.optimization_barrier(x)


@_dtype_pin.defjvp
def _dtype_pin_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _dtype_pin(x), t


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path.
#
# Layout: token activations are batch-sharded over ('pod','data') and
# REPLICATED over 'model'; expert weights are sharded over 'model'
# (E_loc = E/model experts per device).  Every device routes its local
# tokens, scatters the slice destined to ITS experts into a local
# (E_loc, C, D) buffer (zero cross-device traffic for dispatch — the tokens
# are already resident), runs its expert matmuls, and the partial outputs
# are combined with ONE psum over 'model' per MoE layer.
#
# This replaces the XLA-SPMD-derived schedule for the dense-dispatch
# formulation, which replicated the full (T*k, D) dispatch buffer to every
# device (observed: ~9.9 TB/device/step for qwen3-30b prefill_32k — see
# EXPERIMENTS.md §Perf iteration A1).  Capacity is enforced per data shard
# (C = cf*T_loc*k/E), the standard deployment rule.
# ---------------------------------------------------------------------------
def _apply_moe_ep(p: dict, x: jax.Array, cfg: ModelConfig, rules,
                  no_drop: bool) -> tuple[jax.Array, dict]:
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    moe = cfg.moe
    E = moe.n_experts
    m_size = mesh.shape["model"]
    E_loc = E // m_size
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    x_spec = rules.spec_for(("batch", "seq", None), x.shape)
    w_spec = P("model", None, None)
    p_specs = {k: (P() if k == "router" else w_spec) for k in p}
    aux_spec = {"moe_load_balance": P(), "moe_z_loss": P(),
                "moe_drop_frac": P()}

    def local_fn(x_loc, p_loc):
        B, S, d = x_loc.shape
        xt = x_loc.reshape(-1, d)
        T = xt.shape[0]
        K = moe.top_k
        C = T if no_drop else capacity(T, cfg)

        logits = (xt @ p_loc["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, K)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        lo = jax.lax.axis_index("model") * E_loc
        flat_e = top_e.reshape(-1)
        is_local = (flat_e >= lo) & (flat_e < lo + E_loc)
        local_e = jnp.where(is_local, flat_e - lo, E_loc)
        onehot = jax.nn.one_hot(local_e, E_loc, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, -1)
        kept = is_local & (pos < C)
        dst_e = jnp.where(kept, local_e, E_loc)
        dst_c = jnp.where(kept, pos, 0)

        xk = jnp.repeat(xt, K, axis=0)
        buf = jnp.zeros((E_loc + 1, C, d), xt.dtype)
        buf = buf.at[dst_e, dst_c].set(xk, mode="drop")
        ein = buf[:E_loc]
        if "wg" in p_loc:
            h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, p_loc["wg"]))
                 * jnp.einsum("ecd,edf->ecf", ein, p_loc["wu"]))
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ein, p_loc["wi"]),
                            approximate=True)
        eout = jnp.einsum("ecf,efd->ecd", h, p_loc["wd"])

        out_k = eout[jnp.minimum(dst_e, E_loc - 1), dst_c]
        out_k = out_k * kept[:, None].astype(out_k.dtype)
        out_k = out_k * top_p.reshape(-1)[:, None].astype(out_k.dtype)
        partial = jnp.sum(out_k.reshape(T, K, d), axis=1)
        # pin the combine to the model dtype: the barrier stops XLA hoisting
        # the downstream f32 convert above the all-reduce (2x ICI bytes).
        # _dtype_pin wraps the barrier in an identity-tangent custom_jvp so
        # the hint stays active under differentiation on every jax version
        # (jax < 0.5 defines no rule for the raw primitive).
        partial = partial.astype(x_loc.dtype)
        partial = _dtype_pin(partial)
        out = jax.lax.psum(partial, "model")            # combine experts

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32),
                      axis=0)
        aux = {
            "moe_load_balance": E * jnp.sum(me * ce),
            "moe_z_loss": jnp.mean(
                jnp.square(jax.nn.logsumexp(logits, axis=-1))),
            "moe_drop_frac": jax.lax.psum(
                jnp.sum(is_local & ~kept).astype(jnp.float32), "model")
            / (T * K),
        }
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(B, S, d).astype(x_loc.dtype), aux

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(x_spec, p_specs),
                   out_specs=(x_spec, aux_spec))
    return fn(x, p)
