"""Shared model components: norms, RoPE, linear/embedding initializers.

All initializers return ``Annot`` leaves (array + logical sharding axes);
apply functions take plain arrays (after ``partitioning.split``).
Numerically sensitive ops (norms, softmax, rope) compute in float32 and cast
back to the model dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.partitioning import Annot


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": Annot(jnp.ones((d,), dtype), ("embed_nofsdp",))}
    if kind == "ln":
        p["bias"] = Annot(jnp.zeros((d,), dtype), ("embed_nofsdp",))
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-5
               ) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if kind == "rms":
        x32 = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
        out = x32 * p["scale"].astype(jnp.float32)
    elif kind == "ln":
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
        out = (x32 - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


def init_groupnorm(n_groups: int, d: int, dtype) -> dict:
    return {"scale": Annot(jnp.ones((d,), dtype), ("embed_nofsdp",)),
            "bias": Annot(jnp.zeros((d,), dtype), ("embed_nofsdp",))}


def apply_groupnorm(p: dict, x: jax.Array, n_groups: int, eps: float = 1e-5
                    ) -> jax.Array:
    """GroupNorm over the last dim split into n_groups (RWKV head-norm)."""
    *lead, d = x.shape
    x32 = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
    x32 = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    out = x32 * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, axes: tuple, dtype,
                bias: bool = False, bias_axes: tuple | None = None,
                scale: float | None = None) -> dict:
    s = (scale if scale is not None else d_in ** -0.5)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                    jnp.float32) * s
    p = {"w": Annot(w.astype(dtype), axes)}
    if bias:
        p["b"] = Annot(jnp.zeros((d_out,), dtype),
                       bias_axes if bias_axes is not None else (axes[-1],))
    return p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_embedding(key, vocab: int, d: int, dtype) -> Annot:
    e = jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)
    return Annot((e * d ** -0.5).astype(dtype), ("vocab", "embed"))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,dh/2)
    cos = jnp.cos(angles)[..., None, :]                 # (...,S,1,dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : dh // 2], x32[..., dh // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)
