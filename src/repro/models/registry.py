"""Model registry: build models and input specs from an architecture name.

``input_specs`` is the single source of truth for what every (arch x shape)
combination consumes — used identically by smoke tests (materialised) and
the multi-pod dry-run (ShapeDtypeStructs, never allocated).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer

# dense archs get a ring-buffer sliding window for the 500k decode shape
LONG_CONTEXT_WINDOW = 8192


def config_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-specific config adjustments (DESIGN.md §5): full attention at
    524288 decode is replaced by the sliding-window variant for archs with
    no sub-quadratic path of their own (dense/vlm/audio/moe); ssm/hybrid run
    natively."""
    needs_window = (shape.seq_len >= 262_144 and not cfg.attention_free
                    and cfg.attn_every == 0)
    if needs_window and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb_dtype = jnp.dtype(cfg.dtype)

    def tok_struct(*s):
        return jax.ShapeDtypeStruct(s, i32)

    if shape.kind == "decode":
        if cfg.n_codebooks:
            return {"tokens": tok_struct(B, cfg.n_codebooks)}
        return {"tokens": tok_struct(B)}

    specs: dict[str, Any] = {}
    if cfg.n_codebooks:
        specs["tokens"] = tok_struct(B, cfg.n_codebooks, S)
    elif cfg.n_vis_tokens:
        specs["tokens"] = tok_struct(B, S - cfg.n_vis_tokens)
        specs["vis_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vis_tokens, cfg.vis_dim), emb_dtype)
    else:
        specs["tokens"] = tok_struct(B, S)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(specs["tokens"].shape, i32)
    return specs


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array
               ) -> dict[str, jax.Array]:
    """Materialise a random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        k, key = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32
                                          ).astype(s.dtype)
    return out


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key):
        return transformer.init_params(self.cfg, key)

    def abstract_params(self, key=None):
        return transformer.abstract_params(self.cfg, key)

    def init_cache(self, batch: int, max_seq: int):
        return transformer.init_cache(self.cfg, batch, max_seq)

    def abstract_cache(self, batch: int, max_seq: int):
        return transformer.abstract_cache(self.cfg, batch, max_seq)

    def forward(self, params, batch, remat: bool = False,
                inference: bool = False):
        return transformer.forward(params, self.cfg, batch, remat=remat,
                                   inference=inference)

    def prefill(self, params, cache, batch):
        return transformer.prefill(params, self.cfg, cache, batch)

    def decode_step(self, params, cache, batch):
        return transformer.decode_step(params, self.cfg, cache, batch)


def build(arch: str | ModelConfig, shape: ShapeConfig | None = None) -> Model:
    cfg = get_arch(arch) if isinstance(arch, str) else arch
    if shape is not None:
        cfg = config_for_shape(cfg, shape)
    return Model(cfg)
