"""GQA attention: chunked (flash-style) prefill/train and cached decode.

Prefill/train uses a two-level ``lax.scan`` over query and key/value blocks
with online-softmax accumulation — the O(S) working-set formulation required
for 32k prefill.  This is the MobiRNN coarse-factorization rule at the
sequence level: blocks are the work units; their size is the coarseness knob.

Decode attends one new token against a preallocated cache.  Two cache
layouts are supported:
  * full    — (B, S_max, Hkv, dh), position `pos` written in place
  * ring    — sliding-window (B, W, Hkv, dh), slot ``pos % W`` overwritten;
              slot j holds absolute position pos - ((pos - j) mod W)
Ring caches are what make `long_500k` decode possible for dense archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.partitioning import Annot

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv, dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    s = d ** -0.5

    def w(k, shape, axes):
        return Annot((jax.random.truncated_normal(k, -2.0, 2.0, shape,
                                                  jnp.float32) * s
                      ).astype(dtype), axes)

    p = {
        "wq": w(ks[0], (d, hq, dh), ("embed", "heads", None)),
        "wk": w(ks[1], (d, hkv, dh), ("embed", "kv_heads", None)),
        "wv": w(ks[2], (d, hkv, dh), ("embed", "kv_heads", None)),
        "wo": w(ks[3], (hq, dh, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = Annot(jnp.zeros((hq, dh), dtype), ("heads", None))
        p["bk"] = Annot(jnp.zeros((hkv, dh), dtype), ("kv_heads", None))
        p["bv"] = Annot(jnp.zeros((hkv, dh), dtype), ("kv_heads", None))
    return p


def _qkv(p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0, q_block: int = 512,
                    kv_block: int = 1024) -> jax.Array:
    """Causal blockwise attention with grouped GQA (kv is NEVER expanded to
    Hq heads).  q: (B, S, Hq, dh); k,v: (B, S, Hkv, dh), Hq % Hkv == 0.

    window > 0 restricts attention to the last `window` positions.
    """
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qb = min(q_block, S)
    kb = min(kv_block, S)
    assert S % qb == 0 and S % kb == 0, (S, qb, kb)
    nq, nk = S // qb, S // kb
    scale = dh ** -0.5
    qr = (q.reshape(B, nq, qb, Hkv, g, dh).astype(jnp.float32) * scale)
    kr = k.reshape(B, nk, kb, Hkv, dh)
    vr = v.reshape(B, nk, kb, Hkv, dh)

    q_pos = jnp.arange(S).reshape(nq, qb)
    k_pos = jnp.arange(S).reshape(nk, kb)

    def per_q_block(_, qi):
        q_i = qr[:, qi]                       # (B, qb, Hkv, g, dh)
        qp = q_pos[qi]                        # (qb,)
        m0 = jnp.full((B, Hkv, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, g, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, g, qb, dh), jnp.float32)

        def per_kv_block(carry, kj):
            m, l, acc = carry
            k_j = kr[:, kj].astype(jnp.float32)
            v_j = vr[:, kj].astype(jnp.float32)
            kp = k_pos[kj]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j)
            mask = qp[:, None] >= kp[None, :]
            if window:
                mask &= (qp[:, None] - kp[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd",
                                                      p, v_j)
            return (m_new, l, acc), None

        (m, l, acc), _ = jax.lax.scan(per_kv_block, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,g,qb,dh)
        return None, out.transpose(0, 3, 1, 2, 4)     # (B,qb,Hkv,g,dh)

    _, outs = jax.lax.scan(per_q_block, None, jnp.arange(nq))
    # outs: (nq, B, qb, Hkv, g, dh) -> (B, S, Hq, dh)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, dh
                                                    ).astype(q.dtype)


def apply_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array) -> jax.Array:
    """Full-sequence (train/prefill) attention.  x: (B, S, d)."""
    q, k, v = _qkv(p, x)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    out = flash_attention(q, k, v, window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def prefill_cache(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
                  positions: jax.Array) -> dict:
    """Write the (roped) k/v of a full prefill segment into the cache.

    x: (B, S, d); cache arrays (B, S_c, Hkv, dh).  For ring caches only the
    last W positions are written, at their ``pos % W`` slots."""
    _, k, v = _qkv(p, x)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    s_c = cache["k"].shape[1]
    writes = {"k": k, "v": v}
    if cfg.kv_quant:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        writes = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    new = {}
    for name, val in writes.items():
        tgt = cache[name]
        if S <= s_c and not cfg.sliding_window:
            new[name] = jax.lax.dynamic_update_slice_in_dim(
                tgt, val.astype(tgt.dtype), 0, axis=1)
        else:
            keep = min(S, s_c)
            slots = jnp.arange(S - keep, S) % s_c
            new[name] = tgt.at[:, slots].set(
                val[:, -keep:].astype(tgt.dtype))
    return new


def chunk_prefill_attention(p: dict, x: jax.Array, cache: dict,
                            cfg: ModelConfig, positions: jax.Array
                            ) -> tuple[jax.Array, dict]:
    """One fixed-shape prefill CHUNK against the decode cache.

    The chunked-admission middle ground between ``apply_attention`` (whole
    sequence, no cache read) and ``decode_attention`` (one token): x is a
    (B, L, d) slice of the prompt whose absolute positions are
    ``positions`` (B, L) — consecutive, continuing wherever the previous
    chunk stopped.  The chunk's roped k/v are scattered into the cache at
    their position slots (ring slots ``pos % S_c`` for sliding-window
    layouts, mirroring ``decode_attention``), and the chunk's queries
    attend the FULL cache under a content-position validity mask, so
    chunk k sees every key chunks 0..k-1 wrote plus its own causal prefix.

    Token identity with whole-prompt prefill holds as long as the ring
    never evicts a position a later query still needs — i.e. for
    sliding-window layouts only while the whole prompt fits the ring
    (prompt_len <= S_c); the serving engine routes longer windowed
    prompts through the whole-prompt path instead.

    Returns (attention output (B, L, d), cache writes dict).
    """
    from repro.partitioning import constrain

    B, L, _ = x.shape
    q, k, v = _qkv(p, x)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    s_c = cache["k"].shape[1]
    w = cfg.sliding_window or 0
    slots = (positions % s_c) if w else positions     # (B, L) write slots
    b_idx = jnp.arange(B)[:, None]

    def dus(name, val):
        tgt = cache[name]
        return tgt.at[b_idx, slots].set(val.astype(tgt.dtype))

    if cfg.kv_quant:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        new_kv = {"k": dus("k", kq), "v": dus("v", vq),
                  "k_scale": dus("k_scale", ks),
                  "v_scale": dus("v_scale", vs)}
    else:
        new_kv = {"k": dus("k", k), "v": dus("v", v)}
    k_cache, v_cache = new_kv["k"], new_kv["v"]

    hkv = cfg.n_kv_heads
    group = cfg.n_heads // hkv
    dh = cfg.resolved_head_dim
    scale = dh ** -0.5
    q5 = q.reshape(B, L, hkv, group, dh)
    q5 = q5.astype(x.dtype if cfg.kv_quant else k_cache.dtype)
    scores = jnp.einsum("blkgd,bskd->bkgls", q5,
                        k_cache.astype(q5.dtype),
                        preferred_element_type=jnp.float32) * scale
    if cfg.kv_quant:
        # per-(token, head) dequant scales fold into the scores, exactly
        # as in decode_attention
        scores = scores * jnp.swapaxes(new_kv["k_scale"], 1, 2)[:, :, None,
                                                                None]
    scores = constrain(scores, ("batch", None, None, None, "cache_seq"))

    # content-position mask: slot j holds the key of absolute position
    # content_pos[j]; a query at qp may attend it iff 0 <= content_pos <=
    # qp (and within the sliding window).  The same formula covers the
    # full layout (content_pos == j for written slots, negative
    # otherwise) and the ring (latest write wins), including the
    # intra-chunk causal half: slots this chunk wrote for positions > qp
    # resolve to content_pos > qp and are masked.
    idx = jnp.arange(s_c)                             # (S_c,)
    p_last = positions[:, -1][:, None]                # (B, 1) chunk end
    written = jnp.mod(p_last - idx[None], s_c) < L    # (B, S_c)
    prev_last = p_last - L                            # end of chunks 0..k-1
    content_pos = jnp.where(
        written, p_last - jnp.mod(p_last - idx[None], s_c),
        prev_last - jnp.mod(prev_last - idx[None], s_c))
    qp = positions[:, :, None]                        # (B, L, 1)
    cp = content_pos[:, None, :]                      # (B, 1, S_c)
    valid = (cp >= 0) & (cp <= qp)                    # (B, L, S_c)
    if w:
        valid &= (qp - cp) < w
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)           # (B,Hkv,g,L,S_c) f32
    if cfg.kv_quant:
        probs = probs * jnp.swapaxes(new_kv["v_scale"], 1, 2)[:, :, None,
                                                              None]
        out = jnp.einsum("bkgls,bskd->blkgd", probs.astype(x.dtype),
                         v_cache.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bkgls,bskd->blkgd", probs.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    out = out.reshape(B, L, cfg.n_heads, dh).astype(x.dtype)
    y = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    return y, new_kv


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache_slot(cfg: ModelConfig, n_groups: int, batch: int,
                    max_seq: int, dtype) -> dict:
    """Annotated zero KV cache for one attention slot, stacked over groups.

    kv_quant stores int8 values + per-(token, kv-head) float scales —
    halving (vs bf16) the cache bytes streamed per decode step."""
    w = cfg.sliding_window or 0
    s_c = min(max_seq, w) if w else max_seq
    shape = (n_groups, batch, s_c, cfg.n_kv_heads, cfg.resolved_head_dim)
    axes = ("layers", "batch", "cache_seq", "kv_heads", None)
    if cfg.kv_quant:
        sshape = shape[:-1]
        saxes = axes[:-1]
        return {"k": Annot(jnp.zeros(shape, jnp.int8), axes),
                "v": Annot(jnp.zeros(shape, jnp.int8), axes),
                "k_scale": Annot(jnp.zeros(sshape, jnp.float32), saxes),
                "v_scale": Annot(jnp.zeros(sshape, jnp.float32), saxes)}
    return {"k": Annot(jnp.zeros(shape, dtype), axes),
            "v": Annot(jnp.zeros(shape, dtype), axes)}


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(..., head) symmetric int8 quantization over the last dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale


def _dequant(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                     cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token cached attention.  x: (B, 1, d); cache slot arrays
    (B, S_c, Hkv, dh); pos: absolute position of this token — a scalar
    (all lanes in lockstep, the wave engine) or a (B,) vector (each lane
    at its own position, the slot-resident continuous-batching engine).
    The scalar case is exactly the vector case with every lane equal, so
    one code path serves both.

    GQA is computed in GROUPED form (q reshaped to (B, Hkv, group, dh)) so
    the kv cache is never expanded to Hq heads — materialising the repeat
    forced XLA to all-gather the whole seq-sharded cache every layer
    (537MB x 2 x 48 layers/token for yi-9b, §Perf iteration B1).  The
    contractions keep the cache dim shard-local; only the (B,Hkv,g,dh)
    output needs a cross-shard sum."""
    from repro.partitioning import constrain

    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))          # (B,)
    q, k, v = _qkv(p, x)                          # (B,1,h,dh)
    q = common.apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = common.apply_rope(k, pos_b[:, None], cfg.rope_theta)
    s_c = cache["k"].shape[1]
    w = cfg.sliding_window or 0
    slot_b = (pos_b % s_c) if w else pos_b        # (B,) per-lane write slot

    def dus(name, val):
        tgt = cache[name]
        return tgt.at[jnp.arange(B), slot_b].set(
            val[:, 0].astype(tgt.dtype))

    if cfg.kv_quant:
        kq, ks = _quantize(k)
        vq, vs = _quantize(v)
        new_kv = {"k": dus("k", kq), "v": dus("v", vq),
                  "k_scale": dus("k_scale", ks),
                  "v_scale": dus("v_scale", vs)}
    else:
        new_kv = {"k": dus("k", k), "v": dus("v", v)}
    k_cache, v_cache = new_kv["k"], new_kv["v"]

    hkv = cfg.n_kv_heads
    group = cfg.n_heads // hkv
    dh = cfg.resolved_head_dim
    scale = dh ** -0.5
    q4 = q[:, 0].reshape(B, hkv, group, dh)
    q4 = q4.astype(x.dtype if cfg.kv_quant else k_cache.dtype)
    scores = jnp.einsum("bkgd,bskd->bkgs", q4,
                        k_cache.astype(q4.dtype),
                        preferred_element_type=jnp.float32) * scale
    if cfg.kv_quant:
        # fold the per-(token, head) dequant scales into the scores
        scores = scores * jnp.swapaxes(new_kv["k_scale"], 1, 2)[:, :, None]
    scores = constrain(scores, ("batch", None, None, "cache_seq"))
    idx = jnp.arange(s_c)
    if w:
        # slot j holds absolute position pos - ((pos - j) mod S_c)
        slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - idx[None], s_c)
        valid = slot_pos >= 0                     # (B, S_c)
    else:
        valid = idx[None] <= pos_b[:, None]       # (B, S_c)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)        # (B,Hkv,g,S) f32
    if cfg.kv_quant:
        # fold v's dequant scales into the probabilities
        probs = probs * jnp.swapaxes(new_kv["v_scale"], 1, 2)[:, :, None]
        out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(x.dtype),
                         v_cache.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
    out = out.reshape(B, cfg.n_heads, dh).astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y, new_kv
