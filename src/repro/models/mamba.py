"""Mamba (S6 selective SSM) block — the SSM half of the jamba hybrid.

Train/prefill runs a ``lax.scan`` over time chunks with a per-step inner
recurrence (the state (B, d_inner, d_state) is the carry — preallocated and
reused, never re-materialised per step).  Decode is a single-step update over
the cached (conv window, ssm state).

Per-(channel, state) data-dependent decay exp(dt * A) means the matmul-form
chunking used for RWKV6 does not apply (the (C,C) kernel would be per
(channel x state) — see DESIGN.md); the per-step scan is the faithful
Mamba-1 recurrence.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.partitioning import Annot


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def _w(key, shape, axes, scale, dtype):
    return Annot((jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32) * scale
                  ).astype(dtype), axes)


def init_mamba(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di, ds, dc, dr = d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv, dt_rank(cfg)
    ks = jax.random.split(key, 6)
    f32 = jnp.float32
    # S4D-real initialisation of A; dt bias initialised for softplus in
    # [1e-3, 1e-1] (standard mamba init)
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=f32)[None, :], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[4], (di,), f32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log1p(-jnp.exp(-dt_init))  # inverse softplus
    return {
        "in_proj": _w(ks[0], (d, 2 * di), ("embed", "mlp"), d ** -0.5, dtype),
        "conv_w": _w(ks[1], (dc, di), (None, "mlp"), dc ** -0.5, dtype),
        "conv_b": Annot(jnp.zeros((di,), dtype), ("mlp",)),
        "x_proj": _w(ks[2], (di, dr + 2 * ds), ("mlp", None), di ** -0.5, dtype),
        "dt_proj": _w(ks[3], (dr, di), (None, "mlp"), dr ** -0.5, f32),
        "dt_bias": Annot(dt_bias, ("mlp",)),
        "a_log": Annot(jnp.log(a), ("mlp", None)),
        "d_skip": Annot(jnp.ones((di,), f32), ("mlp",)),
        "out_proj": _w(ks[5], (di, d), ("mlp", "embed"), di ** -0.5, dtype),
    }


def _conv_causal(p: dict, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq.  x: (B,S,di); x_prev: (B,dc-1,di)
    carry window from the previous segment."""
    dc = p["conv_w"].shape[0]
    xp = jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(dc):
        # tap i reads position t - (dc-1-i)
        out = out + xp[:, i:i + x.shape[1]] * p["conv_w"][i]
    return out + p["conv_b"]


def _ssm_params(p: dict, cfg: ModelConfig, xc: jax.Array):
    """dt (B,S,di) f32, B/C matrices (B,S,ds) f32 from conv output."""
    dr, ds = dt_rank(cfg), cfg.ssm.d_state
    proj = xc @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dr].astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])
    b_mat = proj[..., dr:dr + ds].astype(jnp.float32)
    c_mat = proj[..., dr + ds:].astype(jnp.float32)
    return dt, b_mat, c_mat


def _scan(p: dict, xc: jax.Array, dt, b_mat, c_mat, h0: jax.Array):
    """Selective scan.  xc: (B,S,di); h0: (B,di,ds) f32."""
    a = -jnp.exp(p["a_log"])                         # (di, ds)

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs                     # (B,di),(B,di),(B,ds)x2
        decay = jnp.exp(dt_t[..., None] * a)         # (B,di,ds)
        dbx = (dt_t * x_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = decay * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.swapaxes(xc, 0, 1), jnp.swapaxes(dt, 0, 1),
          jnp.swapaxes(b_mat, 0, 1), jnp.swapaxes(c_mat, 0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.swapaxes(ys, 0, 1)                        # (B,S,di)
    return y + xc.astype(jnp.float32) * p["d_skip"], h


def scan_summary(p: dict, dt: jax.Array, b_mat: jax.Array
                 ) -> jax.Array:
    """Affine summary of a scan segment: the selective-scan update
    h' = exp(dt⊙A) h + dt·x·B is affine in h, so a segment composes as
    (D_seg, A_seg) with D_seg = exp(Σ_t dt_t ⊙ A) and A_seg = the
    scan-from-zero final state.  This is the primitive that distributes the
    Mamba recurrence across sequence shards exactly like the RWKV wkv
    pipeline (EXPERIMENTS.md §Perf iteration E); validated in
    tests/test_mamba_affine.py."""
    a = -jnp.exp(p["a_log"])                              # (di, ds)
    return jnp.exp(jnp.sum(dt, axis=1)[..., None] * a)    # (B, di, ds)


def compose_affine(d1, a1, d2, a2):
    """(D2,A2)∘(D1,A1): apply segment 1 then segment 2."""
    return d2 * d1, d2 * a1 + a2


def apply_mamba(p: dict, cfg: ModelConfig, x: jax.Array, conv_state, h_state
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence mamba.  x: (B,S,d).  Returns (out, conv', h')."""
    di = d_inner(cfg)
    xz = x @ p["in_proj"]
    x_in, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_conv_causal(p, x_in, conv_state))
    dt, b_mat, c_mat = _ssm_params(p, cfg, xc)
    y, h = _scan(p, xc, dt, b_mat, c_mat, h_state.astype(jnp.float32))
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    dc = cfg.ssm.d_conv
    conv_new = jnp.concatenate([conv_state.astype(x_in.dtype),
                                x_in], axis=1)[:, -(dc - 1):]
    return out, conv_new, h


def step_mamba(p: dict, cfg: ModelConfig, x: jax.Array, conv_state, h_state
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token mamba.  x: (B,1,d); conv_state: (B,dc-1,di);
    h_state: (B,di,ds)."""
    return apply_mamba(p, cfg, x, conv_state, h_state)
