"""Generic decoder assembly for all assigned architectures.

A model is a periodic stack of blocks; each block = (mix, mlp) where
  mix ∈ {attention, rwkv6 time-mix, mamba}   and
  mlp ∈ {dense MLP, MoE, rwkv6 channel-mix}
chosen per slot index by the config (cfg.layer_kind / cfg.layer_is_moe).
Layers are executed with ``lax.scan`` over groups of one period (stacked
parameters) to bound HLO size at 48-72 layer depth.

Three entry points share the block code:
  * forward      — full-sequence, no cache (training / dry-run prefill)
  * prefill      — full-sequence, writes the decode cache (serving)
  * decode_step  — one token against the preallocated cache

Modality fronts (per assignment these are the only stubs in the system):
  * vlm    — precomputed patch embeddings -> learned 2-layer projector,
             prepended to the text sequence
  * audio  — K parallel EnCodec codebook ids, embedded and summed; K output
             heads predict the next token of every codebook
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mamba, mlp, moe, rwkv
from repro.partitioning import Annot, constrain, split


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_slot(key, cfg: ModelConfig, slot: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kind = cfg.layer_kind(slot)
    p: dict = {"ln1": common.init_norm(cfg.d_model, cfg.norm, jnp.float32)}
    if kind == "attn":
        p["mix"] = attention.init_attention(k1, cfg, dtype)
    elif cfg.ssm.kind == "rwkv6":
        p["mix"] = rwkv.init_tmix(k1, cfg, dtype)
    else:
        p["mix"] = mamba.init_mamba(k1, cfg, dtype)
    p["ln2"] = common.init_norm(cfg.d_model, cfg.norm, jnp.float32)
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        p["mlp"] = rwkv.init_cmix(k2, cfg, dtype)
    elif cfg.layer_is_moe(slot):
        p["mlp"] = moe.init_moe(k3, cfg, dtype)
    else:
        p["mlp"] = mlp.init_mlp(k4, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """Annotated parameter tree (run under jax.eval_shape for dry-runs)."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.period
    n_groups = cfg.n_layers // period
    k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)

    p: dict = {}
    if cfg.n_codebooks:
        e = jax.random.truncated_normal(
            k_embed, -2.0, 2.0, (cfg.n_codebooks, cfg.vocab, cfg.d_model),
            jnp.float32) * cfg.d_model ** -0.5
        p["audio_embed"] = Annot(e.astype(dtype), (None, "vocab", "embed"))
    else:
        p["embed"] = common.init_embedding(k_embed, cfg.vocab, cfg.d_model,
                                           dtype)
    if cfg.n_vis_tokens:
        kv1, kv2 = jax.random.split(k_extra)
        p["vis_proj"] = {
            "in": common.init_linear(kv1, cfg.vis_dim, cfg.d_model,
                                     ("embed_nofsdp", "embed"), dtype,
                                     bias=True),
            "out": common.init_linear(kv2, cfg.d_model, cfg.d_model,
                                      ("embed", "embed_nofsdp"), dtype,
                                      bias=True),
        }

    # blocks: tuple over period slots, leaves stacked over groups
    slots = []
    block_keys = jax.random.split(k_blocks, n_groups * period
                                  ).reshape(n_groups, period, 2)
    for s in range(period):
        per_group = [_init_slot(block_keys[g, s], cfg, s, dtype)
                     for g in range(n_groups)]
        stacked = jax.tree.map(
            lambda *leaves: Annot(
                jnp.stack([l.value for l in leaves]),
                ("layers",) + tuple(leaves[0].axes)),
            *per_group,
            is_leaf=lambda x: isinstance(x, Annot))
        slots.append(stacked)
    p["blocks"] = tuple(slots)

    p["final_norm"] = common.init_norm(cfg.d_model, cfg.norm, jnp.float32)
    if cfg.n_codebooks:
        h = jax.random.truncated_normal(
            k_head, -2.0, 2.0, (cfg.n_codebooks, cfg.d_model, cfg.vocab),
            jnp.float32) * cfg.d_model ** -0.5
        p["audio_heads"] = Annot(h.astype(dtype), (None, "embed", "vocab"))
    elif not cfg.tie_embeddings:
        p["lm_head"] = common.init_linear(
            k_head, cfg.d_model, cfg.vocab, ("embed", "vocab"), dtype)
    return p


def abstract_params(cfg: ModelConfig, key=None) -> tuple[Any, Any]:
    """(ShapeDtypeStruct tree, axes tree) without materialising anything."""
    key = key if key is not None else jax.random.PRNGKey(0)
    annot = jax.eval_shape(functools.partial(init_params, cfg), key)
    # eval_shape maps through Annot dataclass?  Annot is not a pytree — the
    # shapes come back as Annot(value=ShapeDtypeStruct).  Split as usual.
    return split(annot)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Annotated zero decode cache (the preallocated state pool contents)."""
    dtype = jnp.dtype(cfg.dtype)
    period = cfg.period
    n_groups = cfg.n_layers // period
    slots = []
    for s in range(period):
        kind = cfg.layer_kind(s)
        if kind == "attn":
            slot = attention.init_cache_slot(cfg, n_groups, batch, max_seq,
                                             dtype)
        elif cfg.ssm.kind == "rwkv6":
            H, dh = rwkv.n_heads(cfg), cfg.ssm.head_dim
            d = cfg.d_model
            slot = {
                "shift_t": Annot(jnp.zeros((n_groups, batch, d), dtype),
                                 ("layers", "batch", "embed_nofsdp")),
                "wkv": Annot(jnp.zeros((n_groups, batch, H, dh, dh),
                                       jnp.float32),
                             ("layers", "batch", "heads", None, None)),
                "shift_c": Annot(jnp.zeros((n_groups, batch, d), dtype),
                                 ("layers", "batch", "embed_nofsdp")),
            }
        else:
            di, ds, dc = (mamba.d_inner(cfg), cfg.ssm.d_state,
                          cfg.ssm.d_conv)
            slot = {
                "conv": Annot(jnp.zeros((n_groups, batch, dc - 1, di), dtype),
                              ("layers", "batch", None, "mlp")),
                "h": Annot(jnp.zeros((n_groups, batch, di, ds), jnp.float32),
                           ("layers", "batch", "mlp", None)),
            }
        slots.append(slot)
    return {"pos": Annot(jnp.zeros((), jnp.int32), ()),
            "slots": tuple(slots)}


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    annot = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))
    return split(annot)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_mlp_slot(slot_p, cfg: ModelConfig, slot: int, x, cache, aux,
                    mode: str):
    """Second half-block (mlp / moe / cmix) with residual."""
    h = common.apply_norm(slot_p["ln2"], x, cfg.norm)
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        out, shift = rwkv.apply_cmix(slot_p["mlp"], h, cache["shift_c"])
        cache = dict(cache, shift_c=shift)
        return x + out, cache, aux
    if cfg.layer_is_moe(slot):
        out, moe_aux = moe.apply_moe(slot_p["mlp"], h, cfg,
                                     no_drop=(mode != "full"))
        for k, v in moe_aux.items():
            aux = dict(aux)
            aux[k] = aux.get(k, 0.0) + v
    else:
        out = mlp.apply_mlp(slot_p["mlp"], h, cfg)
    return x + out, cache, aux


def _dummy_cache_slot(cfg: ModelConfig, slot: int, batch: int) -> dict:
    """Zero-state stand-in when running without a cache (training mode)."""
    kind = cfg.layer_kind(slot)
    dtype = jnp.dtype(cfg.dtype)
    if kind == "attn":
        return {}
    if cfg.ssm.kind == "rwkv6":
        H, dh = rwkv.n_heads(cfg), cfg.ssm.head_dim
        return {"shift_t": jnp.zeros((batch, cfg.d_model), dtype),
                "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
                "shift_c": jnp.zeros((batch, cfg.d_model), dtype)}
    di, ds, dc = mamba.d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    return {"conv": jnp.zeros((batch, dc - 1, di), dtype),
            "h": jnp.zeros((batch, di, ds), jnp.float32)}


def _apply_block(slot_p, cfg: ModelConfig, slot: int, x, cache_slot,
                 positions, pos, aux, mode: str):
    """One block (mix + mlp).  cache_slot has NO group dim here (inside
    scan).  mode: 'full' | 'prefill' | 'prefill_chunk' | 'decode'."""
    kind = cfg.layer_kind(slot)
    x = constrain(x, ("batch", _sax(cfg), None))
    h = common.apply_norm(slot_p["ln1"], x, cfg.norm)
    new_cache = dict(cache_slot)
    if kind == "attn":
        if mode == "decode":
            out, kv = attention.decode_attention(slot_p["mix"], h,
                                                 cache_slot, pos, cfg)
            new_cache.update(kv)
        elif mode == "prefill_chunk":
            out, kv = attention.chunk_prefill_attention(
                slot_p["mix"], h, cache_slot, cfg, positions)
            new_cache.update(kv)
        else:
            out = attention.apply_attention(slot_p["mix"], h, cfg, positions)
            if mode == "prefill":
                new_cache.update(attention.prefill_cache(
                    slot_p["mix"], h, cache_slot, cfg, positions))
    elif cfg.ssm.kind == "rwkv6":
        fn = rwkv.step_tmix if mode == "decode" else rwkv.apply_tmix
        out, shift, state = fn(slot_p["mix"], cfg, h,
                               cache_slot["shift_t"], cache_slot["wkv"])
        new_cache.update(shift_t=shift, wkv=state)
    else:
        fn = mamba.step_mamba if mode == "decode" else mamba.apply_mamba
        out, conv, hst = fn(slot_p["mix"], cfg, h, cache_slot["conv"],
                            cache_slot["h"])
        new_cache.update(conv=conv, h=hst)
    x = x + out
    return _apply_mlp_slot(slot_p, cfg, slot, x, new_cache, aux, mode)


# ---------------------------------------------------------------------------
# Embedding / head fronts
# ---------------------------------------------------------------------------
def _sax(cfg: ModelConfig) -> str:
    """Logical name of the activation sequence axis (sequence parallelism
    shards it over 'model' for cfg.seq_shard archs)."""
    return "seq_model" if cfg.seq_shard else "seq"


def embed_inputs(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    if cfg.n_codebooks:
        toks = batch["tokens"]                          # (B, K, S)
        x = jnp.zeros(toks.shape[:1] + toks.shape[2:]
                      + (cfg.d_model,), jnp.dtype(cfg.dtype))
        for k in range(cfg.n_codebooks):                # sum codebook embeds
            x = x + jnp.take(params["audio_embed"][k], toks[:, k], axis=0)
        return x                                        # (B, S, d)
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = constrain(x, ("batch", _sax(cfg), None))
    if cfg.n_vis_tokens and "vis_embeds" in batch:
        vp = params["vis_proj"]
        v = common.apply_linear(vp["in"], batch["vis_embeds"].astype(x.dtype))
        v = common.apply_linear(vp["out"], jax.nn.gelu(v))
        x = jnp.concatenate([v, x], axis=1)
    return x


def lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    sax = _sax(cfg)
    x = constrain(x, ("batch", sax, None))
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bksv", x, params["audio_heads"])
        logits = constrain(logits, ("batch", None, sax, "vocab"))
    elif cfg.tie_embeddings:
        logits = x @ params["embed"].T
        logits = constrain(logits, ("batch", sax, "vocab"))
    else:
        logits = common.apply_linear(params["lm_head"], x)
        logits = constrain(logits, ("batch", sax, "vocab"))
    return common.softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# ---------------------------------------------------------------------------
# Entry points (take PLAIN param / cache trees, post-split)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            inference: bool = False) -> tuple[jax.Array, dict]:
    """Full-sequence forward, no cache.  Returns (logits, aux).

    inference=True switches MoE layers to drop-free dispatch so the result
    is bit-consistent with the prefill/decode paths."""
    mode = "infer" if inference else "full"
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    period = cfg.period
    dummies = tuple(_dummy_cache_slot(cfg, s, B) for s in range(period))
    aux0 = {}
    if cfg.moe is not None:
        z = jnp.zeros((), jnp.float32)
        aux0 = {"moe_load_balance": z, "moe_z_loss": z, "moe_drop_frac": z}

    def group_fn(carry, group_params):
        x, aux = carry
        for s in range(period):
            x, _, aux = _apply_block(group_params[s], cfg, s, x, dummies[s],
                                     positions, None, aux, mode)
        return (x, aux), None

    fn = jax.checkpoint(group_fn) if remat else group_fn
    (x, aux), _ = jax.lax.scan(fn, (x, aux0), params["blocks"])
    x = common.apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params, cfg, x), aux


def prefill(params, cfg: ModelConfig, cache, batch: dict
            ) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also fills the decode cache.

    Returns (logits of the LAST position, updated cache)."""
    x = embed_inputs(params, cfg, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    period = cfg.period
    aux = {}

    def group_fn(carry, xs):
        x = carry
        group_params, cache_slots = xs
        new_slots = []
        a = {}
        for s in range(period):
            x, new_c, a = _apply_block(group_params[s], cfg, s, x,
                                       cache_slots[s], positions, None, a,
                                       "prefill")
            new_slots.append(new_c)
        return x, tuple(new_slots)

    x, new_slots = jax.lax.scan(group_fn, x,
                                (params["blocks"], cache["slots"]))
    x = common.apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x[:, -1:])
    new_cache = {"pos": jnp.asarray(S, jnp.int32), "slots": new_slots}
    del aux
    return logits, new_cache


def prefill_chunk(params, cfg: ModelConfig, cache, batch: dict
                  ) -> tuple[jax.Array, dict]:
    """One fixed-shape prefill chunk: a (B, L) prompt slice continuing at
    absolute position ``cache['pos']`` (a TRACED scalar, unlike
    ``prefill``'s static S — one compiled executable serves every chunk of
    length L wherever it lands in the prompt).

    Attention scatters the chunk's k/v into the cache and attends the full
    cache under a content-position mask
    (attention.chunk_prefill_attention); rwkv/mamba consume the cache as
    their incoming recurrent state — for them a chunk is mathematically
    just a shorter ``prefill`` that starts from carried state.  Returns
    (logits of the chunk's LAST position, updated cache with
    ``pos += L``) — only the final chunk's logits sample a real token.

    Not valid for vis-token prompts (cfg.n_vis_tokens): the learned
    vis prefix is prepended whole at embed time and cannot be sliced
    into token chunks; callers route those through ``prefill``.
    """
    x = embed_inputs(params, cfg, batch)
    B, L = x.shape[0], x.shape[1]
    base = cache["pos"]
    positions = base + jnp.broadcast_to(jnp.arange(L), (B, L))
    period = cfg.period

    def group_fn(carry, xs):
        x = carry
        group_params, cache_slots = xs
        new_slots = []
        a = {}
        for s in range(period):
            x, new_c, a = _apply_block(group_params[s], cfg, s, x,
                                       cache_slots[s], positions, None, a,
                                       "prefill_chunk")
            new_slots.append(new_c)
        return x, tuple(new_slots)

    x, new_slots = jax.lax.scan(group_fn, x,
                                (params["blocks"], cache["slots"]))
    x = common.apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x[:, -1:])
    new_cache = {"pos": base + jnp.asarray(L, jnp.int32),
                 "slots": new_slots}
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, cache, batch: dict
                ) -> tuple[jax.Array, dict]:
    """One decode step.  batch['tokens']: (B,) or (B,K) audio.
    Returns (logits (B,[K,]vocab), updated cache).

    ``cache['pos']`` may be a scalar (lockstep waves) or a (B,) vector
    (slot-resident continuous batching, serving/slots.py) — attention
    handles both; rwkv/mamba state is positionless either way."""
    toks = batch["tokens"]
    if cfg.n_codebooks:
        x = jnp.zeros((toks.shape[0], 1, cfg.d_model), jnp.dtype(cfg.dtype))
        for k in range(cfg.n_codebooks):
            x = x + jnp.take(params["audio_embed"][k], toks[:, k:k + 1],
                             axis=0)
    else:
        x = jnp.take(params["embed"], toks[:, None], axis=0)
    pos = cache["pos"]
    period = cfg.period

    def group_fn(x, xs):
        group_params, cache_slots = xs
        new_slots = []
        aux = {}
        for s in range(period):
            x, new_c, aux = _apply_block(group_params[s], cfg, s, x,
                                         cache_slots[s], None, pos, aux,
                                         "decode")
            new_slots.append(new_c)
        return x, tuple(new_slots)

    x, new_slots = jax.lax.scan(group_fn, x,
                                (params["blocks"], cache["slots"]))
    x = common.apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x)[:, 0] if not cfg.n_codebooks else \
        lm_logits(params, cfg, x)[:, :, 0]
    new_cache = {"pos": pos + 1, "slots": new_slots}
    return logits, new_cache
