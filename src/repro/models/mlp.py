"""Dense MLP blocks (SwiGLU / GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.partitioning import Annot


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)

    def w(k, shape, axes, scale):
        return Annot((jax.random.truncated_normal(k, -2.0, 2.0, shape,
                                                  jnp.float32) * scale
                      ).astype(dtype), axes)

    if cfg.mlp_act == "swiglu":
        return {
            "wg": w(ks[0], (d, ff), ("embed", "mlp"), d ** -0.5),
            "wu": w(ks[1], (d, ff), ("embed", "mlp"), d ** -0.5),
            "wd": w(ks[2], (ff, d), ("mlp", "embed"), ff ** -0.5),
        }
    return {
        "wi": w(ks[0], (d, ff), ("embed", "mlp"), d ** -0.5),
        "wd": w(ks[2], (ff, d), ("mlp", "embed"), ff ** -0.5),
    }


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = common.gelu(x @ p["wi"])
    return h @ p["wd"]
