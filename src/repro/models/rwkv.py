"""RWKV6 (Finch) blocks: time-mix (wkv recurrence with data-dependent decay)
and channel-mix, with both execution plans:

* chunked scan (default) — MobiRNN-style coarse work units over the sequence
  (matmul form within a chunk, state carried across chunks); mirrors the
  Pallas kernel kernels/wkv6.py and is validated against the per-step oracle.
* per-step scan — the fine-grained reference plan (decode uses its step fn).

Token-shift state and the (dk x dv) wkv state per head are the recurrent
state buffers managed by the preallocated decode cache (core/state.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.partitioning import Annot, shard_map

N_MIX = 5  # w, k, v, r, g interpolation vectors


def _w(key, shape, axes, scale, dtype):
    return Annot((jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                              jnp.float32) * scale
                  ).astype(dtype), axes)


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.ssm.head_dim


def init_tmix(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    r = cfg.ssm.lora_rank
    H, dh = n_heads(cfg), cfg.ssm.head_dim
    ks = jax.random.split(key, 12)
    f32 = jnp.float32
    p = {
        # token-shift interpolation: base mu vectors + data-dependent LoRA
        "maa_x": Annot(jnp.zeros((d,), f32), ("embed_nofsdp",)),
        "maa": Annot(jnp.zeros((N_MIX, d), f32), (None, "embed_nofsdp")),
        "tm_w1": _w(ks[0], (d, N_MIX * 32), ("embed", None), d ** -0.5, f32),
        "tm_w2": _w(ks[1], (N_MIX, 32, d), (None, None, "embed"), 32 ** -0.5, f32),
        # data-dependent decay: w0 + LoRA(xw)
        "w0": Annot(jnp.linspace(-6.0, -0.3, d, dtype=f32), ("embed_nofsdp",)),
        "td_w1": _w(ks[2], (d, r), ("embed", None), d ** -0.5, f32),
        "td_w2": _w(ks[3], (r, d), (None, "embed"), r ** -0.5, f32),
        # projections
        "wr": _w(ks[4], (d, d), ("embed", "mlp"), d ** -0.5, dtype),
        "wk": _w(ks[5], (d, d), ("embed", "mlp"), d ** -0.5, dtype),
        "wv": _w(ks[6], (d, d), ("embed", "mlp"), d ** -0.5, dtype),
        "wg": _w(ks[7], (d, d), ("embed", "mlp"), d ** -0.5, dtype),
        "wo": _w(ks[8], (d, d), ("mlp", "embed"), d ** -0.5, dtype),
        # per-head bonus u
        "u": Annot(jnp.zeros((H, dh), f32), ("heads", None)),
        "gn": common.init_groupnorm(H, d, f32),
    }
    return p


def _ddlerp(p: dict, x: jax.Array, sx: jax.Array) -> tuple[jax.Array, ...]:
    """Data-dependent token-shift interpolation (rwkv6 'ddlerp')."""
    B, S, d = x.shape
    xxx = x + sx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["tm_w1"]).reshape(B, S, N_MIX, 32)
    mixes = jnp.einsum("bsnr,nrd->nbsd", lora, p["tm_w2"])   # (5,B,S,d)
    outs = []
    for i in range(N_MIX):
        outs.append(x + sx * (p["maa"][i] + mixes[i]))
    return tuple(outs)  # xw, xk, xv, xr, xg


def _project(p: dict, cfg: ModelConfig, x: jax.Array, x_prev: jax.Array):
    """Common head: token shift + ddlerp + projections.

    x: (B,S,d); x_prev: (B,d) last token of the previous segment.
    Returns r,k,v,g (B,S,H,*), logw (B,S,H,dk), new shift state (B,d).
    """
    B, S, d = x.shape
    H, dh = n_heads(cfg), cfg.ssm.head_dim
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    sx = shifted - x
    xw, xk, xv, xr, xg = _ddlerp(p, x.astype(jnp.float32),
                                 sx.astype(jnp.float32))
    dt = x.dtype
    r = (xr.astype(dt) @ p["wr"]).reshape(B, S, H, dh)
    k = (xk.astype(dt) @ p["wk"]).reshape(B, S, H, dh)
    v = (xv.astype(dt) @ p["wv"]).reshape(B, S, H, dh)
    g = jax.nn.silu(xg.astype(dt) @ p["wg"])
    w = p["w0"] + jnp.tanh(xw @ p["td_w1"]) @ p["td_w2"]      # (B,S,d) f32
    logw = -jnp.exp(w.reshape(B, S, H, dh))                   # <= 0
    return r, k, v, g, logw, x[:, -1]


def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Batched chunked wkv scan.  r,k,logw: (B,S,H,dk); v: (B,S,H,dv);
    u: (H,dk); state: (B,H,dk,dv).  Returns (out, state')."""
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    f32 = jnp.float32

    def to_chunks(a):
        return a.reshape(B, n, chunk, H, -1).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))  # (n,B,H,C,*)
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def step(s, xs):
        rr, kk, vv, ww = (a.astype(f32) for a in xs)   # (B,H,C,*)
        L = jnp.cumsum(ww, axis=2)
        L_prev = L - ww
        out = jnp.einsum("bhck,bhkv->bhcv", rr * jnp.exp(L_prev), s)
        # mask the exponent, not the scores: j >= i entries are positive
        # and would overflow exp under strong decay, NaN-ing the VJP
        diff = L_prev[:, :, :, None, :] - L[:, :, None, :, :]
        diff = jnp.exp(jnp.where(mask[..., None], diff, -jnp.inf))
        scores = jnp.einsum("bhik,bhjk,bhijk->bhij", rr, kk, diff)
        out = out + jnp.einsum("bhij,bhjv->bhiv", scores, vv)
        bonus = jnp.einsum("bhck,hk,bhck->bhc", rr, u.astype(f32), kk)
        out = out + bonus[..., None] * vv
        L_last = L[:, :, -1]
        decay_j = jnp.exp(L_last[:, :, None, :] - L)
        s_new = (jnp.exp(L_last)[..., None] * s
                 + jnp.einsum("bhck,bhcv->bhkv", kk * decay_j, vv))
        return s_new, out

    state, outs = jax.lax.scan(step, state.astype(f32), (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    return out, state


def wkv_step(r, k, v, logw, u, state):
    """Single decode step.  r,k,logw: (B,H,dk); v: (B,H,dv);
    state: (B,H,dk,dv)."""
    f32 = jnp.float32
    r, k, v, logw = (a.astype(f32) for a in (r, k, v, logw))
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u.astype(f32)[..., None] * kv)
    state = jnp.exp(logw)[..., None] * state + kv
    return out, state


def apply_tmix(p: dict, cfg: ModelConfig, x: jax.Array, x_prev: jax.Array,
               state: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix.  Returns (out, shift', state').

    Under an active sharding-rules context with cfg.seq_shard and a >1
    'model' axis, the sequence-parallel pipeline (_apply_tmix_seqpar) runs:
    the residual stream stays sequence-sharded and the wkv recurrence is
    distributed with an affine-prefix exchange — the MobiRNN wavefront
    across chips."""
    from repro import partitioning as pt

    B, S, d = x.shape
    if cfg.seq_shard and pt._ACTIVE_RULES:
        rules = pt._ACTIVE_RULES[-1]
        m = rules.mesh.shape.get("model", 1)
        if m > 1 and S % m == 0 and (S // m) >= 4:
            return _apply_tmix_seqpar(p, cfg, x, x_prev, state, rules)
    return _apply_tmix_local(p, cfg, x, x_prev, state)


#: default core/plans.RWKV_PLANS plan for the full-sequence scan — the
#: registry is the single decision table for which wkv execution runs
#: ("chunked_xla" wraps wkv_chunked below; "chunked_scan" is the fused
#: Pallas kernel; "stepwise" the per-step oracle).  Override per call via
#: ``_apply_tmix_local(..., plan=...)`` or globally for experiments.
WKV_PLAN = "chunked_xla"


def _apply_tmix_local(p, cfg, x, x_prev, state, plan: str | None = None):
    from repro.core import plans as plans_lib

    B, S, d = x.shape
    H = n_heads(cfg)
    r, k, v, g, logw, shift = _project(p, cfg, x, x_prev)
    wkv_fn = plans_lib.RWKV_PLANS[plan or WKV_PLAN]
    out, state = wkv_fn(r, k, v, logw, p["u"], state, chunk=cfg.ssm.chunk)
    out = common.apply_groupnorm(p["gn"], out.reshape(B, S, d), H)
    out = (out.astype(x.dtype) * g) @ p["wo"]
    return out, shift, state


def _apply_tmix_seqpar(p: dict, cfg: ModelConfig, x: jax.Array,
                       x_prev: jax.Array, state: jax.Array, rules
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sequence-parallel time-mix.

    Activations arrive sequence-sharded over the 'model' axis.  Everything
    per-token (ddlerp, projections, groupnorm, gating) is shard-local; the
    only cross-chip parts are

      1. token shift: the last token of shard i is the shift input of
         shard i+1 — one (B, d) collective-permute;
      2. the wkv state carry: the per-shard scan summary is affine in the
         incoming state, ``S_out = D ⊙ S_in + A`` with D = exp(Σ logw) and
         A = scan-from-zero final state, so the global recurrence is an
         exclusive prefix over shards of affine maps — computed with
         ceil(log2(m)) Hillis-Steele collective-permute rounds of
         (B, H, dk, dv)-sized pairs;
      3. one correction matmul folding the incoming state into the local
         outputs: out_t += (r_t ⊙ exp(L_prev,t)) @ S_in.

    vs. the XLA-derived tensor-parallel layout this removes ~14 full
    (B, S, d) all-gathers/all-reduces per layer (§Perf iteration C1).
    """
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    m_size = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    B, S, d = x.shape
    H, dh = n_heads(cfg), cfg.ssm.head_dim

    x_spec = rules.spec_for(("batch", "seq_model", None), x.shape)
    bvec_spec = rules.spec_for(("batch", None), (B, d))
    st_spec = rules.spec_for(("batch", None, None, None), state.shape)
    p_spec = jax.tree.map(lambda _: P(), p)

    def local_fn(x_loc, x_prev_g, s0_g, p_loc):
        idx = jax.lax.axis_index("model")
        B_loc, S_loc = x_loc.shape[0], x_loc.shape[1]
        # --- 1. token shift across the shard boundary ------------------
        last = x_loc[:, -1]
        recv = jax.lax.ppermute(last, "model",
                                [(i, (i + 1) % m_size)
                                 for i in range(m_size)])
        xp = jnp.where(idx == 0, x_prev_g.astype(x_loc.dtype), recv)
        r, k, v, g, logw, _ = _project(p_loc, cfg, x_loc, xp)

        # --- 2. local scan from zero + affine summary ------------------
        chunk = cfg.ssm.chunk
        while S_loc % chunk:
            chunk -= 1
        zero = jnp.zeros((B_loc, H, dh, dh), jnp.float32)
        out0, a_loc = wkv_chunked(r, k, v, logw, p_loc["u"], zero, chunk)
        d_loc = jnp.exp(jnp.sum(logw.astype(jnp.float32), axis=1))  # B,H,dk

        # inclusive Hillis-Steele prefix of (D, A) over the model axis
        d_agg, a_agg = d_loc, a_loc
        shift_amt = 1
        while shift_amt < m_size:
            perm = [(i, i + shift_amt) for i in range(m_size - shift_amt)]
            d_r = jax.lax.ppermute(d_agg, "model", perm)
            a_r = jax.lax.ppermute(a_agg, "model", perm)
            has = idx >= shift_amt
            # compose: earlier segment (recv) then mine:
            #   D = D_mine * D_recv ; A = D_mine ⊙ A_recv + A_mine
            d_new = jnp.where(has, d_agg * d_r, d_agg)
            a_new = jnp.where(has, d_agg[..., None] * a_r + a_agg, a_agg)
            d_agg, a_agg = d_new, a_new
            shift_amt *= 2
        # exclusive prefix = inclusive of shard i-1 (shard 0: global s0)
        perm1 = [(i, i + 1) for i in range(m_size - 1)]
        a_excl = jax.lax.ppermute(a_agg, "model", perm1)
        d_excl = jax.lax.ppermute(d_agg, "model", perm1)
        s0 = s0_g.astype(jnp.float32)
        s_in = jnp.where(idx == 0, s0,
                         a_excl + d_excl[..., None] * s0)

        # --- 3. fold the carry into local outputs ----------------------
        lw32 = logw.astype(jnp.float32)
        l_prev = jnp.cumsum(lw32, axis=1) - lw32          # (B,S,H,dk)
        carry = jnp.einsum("bshk,bhkv->bshv",
                           r.astype(jnp.float32) * jnp.exp(l_prev), s_in)
        out = out0 + carry

        # final state (replicated): inclusive aggregate of the last shard
        s_fin = a_agg + d_agg[..., None] * s0
        s_fin = jnp.where(idx == m_size - 1, s_fin, jnp.zeros_like(s_fin))
        s_fin = jax.lax.psum(s_fin, "model")
        # shift state = globally-last token (replicated)
        shift = jnp.where(idx == m_size - 1, x_loc[:, -1],
                          jnp.zeros_like(x_loc[:, -1]))
        shift = jax.lax.psum(shift, "model")

        out = common.apply_groupnorm(p_loc["gn"],
                                     out.reshape(B_loc, S_loc, d), H)
        out = (out.astype(x_loc.dtype) * g) @ p_loc["wo"]
        return out, shift.astype(x_loc.dtype), s_fin

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, bvec_spec, st_spec, p_spec),
        out_specs=(x_spec, bvec_spec, st_spec))
    return fn(x, x_prev, state, p)


def step_tmix(p: dict, cfg: ModelConfig, x: jax.Array, x_prev: jax.Array,
              state: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token time-mix.  x: (B,1,d)."""
    B, _, d = x.shape
    H, dh = n_heads(cfg), cfg.ssm.head_dim
    r, k, v, g, logw, shift = _project(p, cfg, x, x_prev)
    out, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], p["u"],
                          state)
    out = common.apply_groupnorm(p["gn"], out.reshape(B, 1, d), H)
    out = (out.astype(x.dtype) * g) @ p["wo"]
    return out, shift, state


# ---------------------------------------------------------------------------
# Channel-mix
# ---------------------------------------------------------------------------
def init_cmix(key, cfg: ModelConfig, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": Annot(jnp.zeros((d,), jnp.float32), ("embed_nofsdp",)),
        "mu_r": Annot(jnp.zeros((d,), jnp.float32), ("embed_nofsdp",)),
        "wk": _w(ks[0], (d, ff), ("embed", "mlp"), d ** -0.5, dtype),
        "wv": _w(ks[1], (ff, d), ("mlp", "embed"), ff ** -0.5, dtype),
        "wr": _w(ks[2], (d, d), ("embed", "mlp"), d ** -0.5, dtype),
    }


def apply_cmix(p: dict, x: jax.Array, x_prev: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Channel-mix with token shift.  x: (B,S,d); x_prev: (B,d)."""
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    sx = (shifted - x).astype(x.dtype)
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out, x[:, -1]
