"""Synthetic human-activity-recognition dataset (UCI HAR shape-compatible).

MobiRNN evaluates a stacked LSTM on the UCI smartphone dataset [Anguita et
al. 2013]: windows of 128 readings x 9 sensor channels (body acc xyz, gyro
xyz, total acc xyz), 6 activity labels, 7352 train / 2947 test windows.
The dataset is not bundled offline, so we synthesise a generator with the
same shape and a class-conditional signal structure (per-class fundamental
frequency, amplitude, gravity orientation and noise floor chosen to mimic
walking/upstairs/downstairs/sitting/standing/laying).  The classes are
separable but not trivially so (shared harmonics, overlapping noise), which
is what an activity classifier needs to earn its accuracy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

CLASSES = ("walking", "upstairs", "downstairs", "sitting", "standing",
           "laying")
N_CHANNELS = 9
SEQ_LEN = 128

# per-class (fundamental Hz @50Hz sampling, dynamic amplitude, noise, gravity)
_PROFILE = {
    0: (2.0, 1.00, 0.25, (0.0, 0.0, 1.0)),    # walking
    1: (1.6, 1.20, 0.30, (0.2, 0.0, 0.95)),   # upstairs
    2: (2.3, 1.35, 0.35, (-0.2, 0.0, 0.95)),  # downstairs
    3: (0.0, 0.08, 0.10, (0.5, 0.5, 0.70)),   # sitting
    4: (0.0, 0.05, 0.08, (0.0, 0.0, 1.0)),    # standing
    5: (0.0, 0.04, 0.06, (0.0, 1.0, 0.05)),   # laying
}


def _window(rng: np.random.Generator, label: int) -> np.ndarray:
    f0, amp, noise, grav = _PROFILE[label]
    t = np.arange(SEQ_LEN) / 50.0
    x = np.zeros((SEQ_LEN, N_CHANNELS), np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    f = f0 * rng.uniform(0.85, 1.15) if f0 else 0.0
    for c in range(3):                       # body acceleration
        h1 = amp * np.sin(2 * np.pi * f * t + phase + c * 2.1) if f else 0.0
        h2 = 0.3 * amp * np.sin(4 * np.pi * f * t + phase) if f else 0.0
        x[:, c] = h1 + h2
    for c in range(3):                       # gyro: phase-shifted derivative
        x[:, 3 + c] = (0.6 * amp * np.cos(2 * np.pi * f * t + phase + c)
                       if f else 0.0)
    for c in range(3):                       # total acc = body + gravity
        x[:, 6 + c] = x[:, c] + grav[c] * rng.uniform(0.95, 1.05)
    x += rng.normal(0, noise, x.shape).astype(np.float32)
    return x


@dataclasses.dataclass
class HARData:
    x: np.ndarray          # (N, 128, 9) float32
    y: np.ndarray          # (N,) int32


def make_har(n_train: int = 7352, n_test: int = 2947, seed: int = 0
             ) -> tuple[HARData, HARData]:
    rng = np.random.default_rng(seed)

    def gen(n):
        ys = rng.integers(0, len(CLASSES), n).astype(np.int32)
        xs = np.stack([_window(rng, int(y)) for y in ys])
        return HARData(xs, ys)

    return gen(n_train), gen(n_test)


def batches(data: HARData, batch_size: int, seed: int = 0, epochs: int = 10**9):
    rng = np.random.default_rng(seed)
    n = len(data.y)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            yield data.x[idx], data.y[idx]
