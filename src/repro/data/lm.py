"""Synthetic language-model token pipeline.

A second-order structured stream: the next token is a deterministic mixture
of affine maps of the previous two tokens plus Zipfian "function words",
giving a corpus whose cross-entropy is learnably below the uniform bound —
enough structure to verify end-to-end training dynamics without bundling a
real corpus offline.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        self.a = int(rng.integers(3, 23)) * 2 + 1
        self.b = int(rng.integers(1, vocab))
        # Zipfian function-word table
        ranks = np.arange(1, 65)
        p = 1.0 / ranks
        self.fw_p = (p / p.sum()).astype(np.float64)
        self.fw = rng.integers(0, vocab, 64)

    def sample(self, rng: np.random.Generator, batch: int, seq: int
               ) -> np.ndarray:
        out = np.empty((batch, seq), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, batch)
        out[:, 1] = rng.integers(0, self.vocab, batch)
        for t in range(2, seq):
            det = (self.a * out[:, t - 1] + out[:, t - 2] + self.b) % self.vocab
            fw = self.fw[rng.choice(64, batch, p=self.fw_p)]
            use_fw = rng.random(batch) < 0.25
            noise = rng.random(batch) < 0.05
            rnd = rng.integers(0, self.vocab, batch)
            out[:, t] = np.where(noise, rnd, np.where(use_fw, fw, det))
        return out.astype(np.int32)

    def batches(self, batch: int, seq: int, seed: int = 1):
        rng = np.random.default_rng(seed)
        while True:
            toks = self.sample(rng, batch, seq)
            yield {"tokens": toks}
