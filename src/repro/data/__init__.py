from repro.data.har import HARData, batches, make_har
from repro.data.lm import SyntheticLM

__all__ = ["HARData", "batches", "make_har", "SyntheticLM"]
