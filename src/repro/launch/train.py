"""Training driver.

Runs real training on the local device(s) for any registered architecture
(typically a ``--reduced`` variant on CPU) against the synthetic LM pipeline,
with sharded params (logical-axis rules on the host mesh), checkpointing and
metric logging.  The same step function lowers against the production mesh
in the dry-run — this driver is the single-host instantiation.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import numpy as np

from repro import steps as steps_lib
from repro.checkpoint import ckpt
from repro.configs import get_arch
from repro.data.lm import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.optim import AdamW, warmup_cosine
from repro.partitioning import (make_rules, param_count, split,
                                tree_shardings, use_rules)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch + ("-reduced" if args.reduced else ""))
    model = registry.build(cfg)
    mesh = make_host_mesh()
    rules = make_rules(mesh)

    params_annot = model.init(jax.random.PRNGKey(args.seed))
    params, axes = split(params_annot)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"mesh={dict(mesh.shape)}")

    optimizer = AdamW(lr=warmup_cosine(args.lr, args.steps // 10,
                                       args.steps))
    opt_state = optimizer.init(params)

    p_shard = tree_shardings(axes, params, rules)
    params = jax.device_put(params, p_shard)

    step_fn = jax.jit(
        functools.partial(steps_lib.train_step, optimizer, cfg),
        donate_argnums=(0, 1))

    data = SyntheticLM(cfg.vocab, seed=args.seed)
    it = data.batches(args.batch, args.seq)

    history = []
    log_every = max(args.log_every, 1)   # --log-every 0 means "every step"
    t0 = time.time()
    with mesh, use_rules(rules):
        for step in range(1, args.steps + 1):
            batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % log_every == 0 or step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = round(time.time() - t0, 1)
                history.append(m)
                print(json.dumps({k: (round(v, 4) if isinstance(v, float)
                                      else v) for k, v in m.items()}))
            if args.ckpt_dir and step % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step, params,
                          {"arch": cfg.name})
    if not history:                      # --steps 0: nothing ran, no summary
        print("no training steps run")
        return
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
