"""Serving driver: batched requests through the MobiRNN-policy engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 8 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.scheduler import SyntheticLoadSensor
from repro.models import registry
from repro.partitioning import split
from repro.serving import Engine, Request, SlotEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--engine", choices=("wave", "slot"), default="slot",
                    help="wave = lockstep batches; slot = slot-resident "
                         "continuous batching (default)")
    ap.add_argument("--load", type=float, default=0.0,
                    help="injected accelerator load in [0,1] (paper Fig 7)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch + ("-reduced" if args.reduced else ""))
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(args.seed)))

    rng = np.random.default_rng(args.seed)
    shape = ((cfg.n_codebooks, args.prompt_len) if cfg.n_codebooks
             else (args.prompt_len,))
    reqs = [Request(i, rng.integers(0, cfg.vocab, shape).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    max_seq = args.prompt_len + args.max_new + 1
    if args.engine == "slot":
        engine = SlotEngine(model, params, n_slots=args.batch_size,
                            max_seq=max_seq,
                            queue_capacity=max(args.requests, 1),
                            sensor=SyntheticLoadSensor(args.load))
    else:
        engine = Engine(model, params, batch_size=args.batch_size,
                        max_seq=max_seq,
                        sensor=SyntheticLoadSensor(args.load))
    t0 = time.time()
    results = engine.serve(reqs)
    wall = time.time() - t0
    n_tok = sum(r.tokens.shape[-1] for r in results)
    print(f"arch={cfg.name} served={len(results)} new_tokens={n_tok} "
          f"wall={wall:.2f}s tok/s={n_tok / wall:.1f}")
    for r in results[:4]:
        print(f"  req {r.uid}: prefill={r.prefill_s * 1e3:.1f}ms "
              f"decode={r.decode_s * 1e3:.1f}ms plans={set(r.plan_decisions)}")
    print("pool:", engine.pool.stats)


if __name__ == "__main__":
    main()
