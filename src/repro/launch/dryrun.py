"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination against the production mesh and extract the roofline
terms from the compiled artifact.

For every combination this:
  1. builds abstract (ShapeDtypeStruct) params / optimizer state / cache /
     batch — nothing is ever allocated;
  2. resolves shardings through the logical-axis rules (partitioning.py);
  3. ``jax.jit(step, in_shardings=...).lower(...).compile()`` — a failure
     here (sharding mismatch, unsupported collective) is a bug in the
     framework, not an acceptable outcome;
  4. records memory_analysis / cost_analysis / per-collective bytes and the
     derived roofline terms to results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun                    # all missing combos
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh pod1
"""
from __future__ import annotations

# The dry-run needs 512 placeholder devices so jax.make_mesh can build the
# production mesh; jax locks the device count on first init, so this MUST
# happen before ANY other import (including `from repro...`).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback

import jax

from repro import analysis, steps
from repro.configs import ARCHS, INPUT_SHAPES, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.models import registry, transformer
from repro.optim import AdamW
from repro.partitioning import make_rules, tree_shardings

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _batch_axes(cfg, shape, specs) -> dict:
    """Logical axes for each batch input."""
    sax = "seq_model" if cfg.seq_shard else "seq"
    axes = {}
    for name, s in specs.items():
        if name == "vis_embeds":
            axes[name] = ("batch", None, None)
        elif cfg.n_codebooks and s.ndim >= 2:
            axes[name] = ("batch", None, sax)[: s.ndim]
        else:
            axes[name] = ("batch", sax)[: s.ndim]
    return axes


def build_case(arch: str, shape_name: str, multi_pod: bool,
               kv_quant: bool = False, data_axis: int = 16,
               model_axis: int = 16):
    """Returns (jitted_fn, abstract_args, meta) ready to lower."""
    import dataclasses

    shape = get_shape(shape_name)
    cfg = registry.config_for_shape(get_arch(arch), shape)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    mesh = make_production_mesh(multi_pod=multi_pod, data=data_axis,
                                model=model_axis)
    # decode: no FSDP — re-gathering weight shards every token costs more
    # ICI than the HBM they save; weights stay model-sharded + replicated
    # over data (§Perf iteration B2)
    overrides = {"embed": ()} if shape.kind == "decode" else None
    rules = make_rules(mesh, overrides)

    params_abs, params_axes = transformer.abstract_params(cfg)
    p_shard = tree_shardings(params_axes, params_abs, rules)
    specs = registry.input_specs(cfg, shape)
    b_axes = _batch_axes(cfg, shape, specs)
    b_shard = {k: rules.sharding_for(b_axes[k], s.shape)
               for k, s in specs.items()}

    if shape.kind == "train":
        optimizer = AdamW(lr=3e-4)
        opt_abs = jax.eval_shape(optimizer.init, params_abs)
        o_shard = {"mu": p_shard, "nu": p_shard,
                   "step": rules.sharding_for((), ())}

        def fn(params, opt_state, batch):
            return steps.train_step(optimizer, cfg, params, opt_state, batch)

        jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        args = (params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        cache_abs, cache_axes = transformer.abstract_cache(
            cfg, shape.global_batch, shape.seq_len)
        c_shard = tree_shardings(cache_axes, cache_abs, rules)

        def fn(params, cache, batch):
            return steps.prefill_step(cfg, params, cache, batch)

        jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, b_shard),
                         donate_argnums=(1,))
        args = (params_abs, cache_abs, specs)
    else:  # decode
        cache_abs, cache_axes = transformer.abstract_cache(
            cfg, shape.global_batch, shape.seq_len)
        c_shard = tree_shardings(cache_axes, cache_abs, rules)

        def fn(params, cache, batch):
            return steps.decode_step(cfg, params, cache, batch)

        jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, b_shard),
                         donate_argnums=(1,))
        args = (params_abs, cache_abs, specs)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "pod2" if multi_pod else "pod1",
            "n_chips": 512 if multi_pod else 256,
            "kind": shape.kind}
    return jitted, args, (cfg, shape, mesh, rules, meta)


def run_case(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str) -> dict:
    from repro import partitioning

    t0 = time.time()
    jitted, args, (cfg, shape, mesh, rules, meta) = build_case(
        arch, shape_name, multi_pod)
    with mesh, partitioning.use_rules(rules):
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = None
        try:
            ma = compiled.memory_analysis()
            mem = {k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            mem = {"error": str(e)[:200]}

        cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            cost = {k: float(v) for k, v in ca.items()
                    if isinstance(v, (int, float)) and (
                        "flops" in k or "bytes" in k)}
        except Exception as e:
            cost = {"error": str(e)[:200]}

        hlo = compiled.as_text()
        coll = analysis.collective_bytes(hlo)

    # compute/memory terms come from the analytic itemized model (XLA's
    # cost_analysis counts while-loop bodies once — recorded as cross-check)
    costs = analysis.analytic_costs(cfg, shape)
    roof = analysis.Roofline(
        flops=costs["flops"],
        hbm_bytes=costs["bytes"],
        coll_bytes=coll,
        n_chips=meta["n_chips"],
        model_flops=analysis.model_flops(cfg, shape),
    )
    rec = dict(meta)
    rec.update(
        ok=True,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory_analysis=mem,
        cost_analysis_hlo=cost,
        analytic=costs,
        params=analysis.param_counts(cfg),
        roofline=roof.to_dict(),
        sliding_window=cfg.sliding_window,
        hlo_bytes_text=len(hlo),
    )
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{meta['mesh']}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod1", "pod2"])
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [args.mesh] if args.mesh else ["pod1", "pod2"]

    failures = []
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                fname = os.path.join(args.out,
                                     f"{arch}__{shape_name}__{mesh_name}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"skip {arch} {shape_name} {mesh_name} (done)")
                    continue
                print(f"== {arch} {shape_name} {mesh_name} ...", flush=True)
                try:
                    rec = run_case(arch, shape_name, mesh_name == "pod2",
                                   args.out)
                    r = rec["roofline"]
                    print(f"   ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"t=({r['t_compute_s']:.2e},"
                          f"{r['t_memory_s']:.2e},"
                          f"{r['t_collective_s']:.2e})s "
                          f"useful={r['useful_flops_frac']:.2f}",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mesh_name, str(e)))
                    os.makedirs(args.out, exist_ok=True)
                    with open(fname + ".fail", "w") as f:
                        f.write(traceback.format_exc())
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_[:3])
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
