"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax use).

Production topology (TPU v5e): one pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods as (pod=2, data=16, model=16).  The 'pod' axis carries
only data parallelism (gradient all-reduce across DCN/ICI), 'model' carries
tensor/expert/sequence parallelism, 'data' carries batch + FSDP weight
sharding.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False,
                         data: int = 16, model: int = 16):
    """Default production topology is (16, 16) / (2, 16, 16); `data`/`model`
    allow aspect-ratio ablations over the same 256 chips per pod
    (EXPERIMENTS.md §Perf iteration D)."""
    assert data * model == 256, (data, model)
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " before any jax import (see launch/dryrun.py)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_axis: int = 1):
    """Tiny mesh on the real local device(s) for tests/examples."""
    devices = jax.devices()
    n = len(devices)
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"),
                         devices=devices[: data * model_axis])
