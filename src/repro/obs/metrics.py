"""Serving metrics: plain-Python counters, gauges, bounded histograms.

The serving invariant these must respect: after warmup the slot engine
performs ZERO device allocations per tick (StatePool.stats.buffers_built
stays at capacity).  Everything here is host-side — ints, floats, and a
bounded ``collections.deque`` — so metrics can stay enabled on the hot
path unconditionally.  Histograms are bounded (default 4096 samples,
matching Scheduler.MAX_DECISIONS) so a long-lived engine is not a slow
host-memory leak.

Percentiles use nearest-rank on a sorted snapshot — exact for the sample
sizes here, no interpolation surprises at p99 with small n.

Serving instruments (pre-created by SlotEngine so snapshots always carry
the full schema): counters serving/{ticks,tokens,retired,deadline_miss,
quarantined,retries,shed}; histograms serving/ttft_s (admission -> first
token host-visible — under chunked prefill this spans every interleaved
chunk, the TTFT-under-contention number the adversary benchmarks bound),
serving/tbt_s, and serving/prefill_chunk_s (per fixed-shape chunk
dispatch; chunked mode only).
"""
from __future__ import annotations

import collections
import math


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value


class Histogram:
    """Bounded reservoir of the most recent ``maxlen`` observations."""
    __slots__ = ("_values",)

    DEFAULT_MAXLEN = 4096

    def __init__(self, maxlen: int = DEFAULT_MAXLEN):
        self._values: collections.deque[float] = collections.deque(maxlen=maxlen)

    def observe(self, value: float) -> None:
        self._values.append(value)

    @property
    def count(self) -> int:
        return len(self._values)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile; NaN when empty."""
        if not self._values:
            return math.nan
        vals = sorted(self._values)
        rank = max(1, math.ceil((p / 100.0) * len(vals)))
        return vals[rank - 1]

    def summary(self) -> dict[str, float]:
        if not self._values:
            return {"count": 0, "p50": math.nan, "p99": math.nan,
                    "mean": math.nan, "max": math.nan}
        vals = list(self._values)
        return {
            "count": len(vals),
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "mean": sum(vals) / len(vals),
            "max": max(vals),
        }


class Metrics:
    """Get-or-create registry.  Names are flat strings — the serving
    engines use a ``serving/`` prefix (see ROADMAP §Observability)."""

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram()
        return h

    def snapshot(self) -> dict[str, dict]:
        """JSON-able view of every instrument — traced at end of a run."""
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }
