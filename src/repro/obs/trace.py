"""Structured tracing: spans + events over a pluggable sink.

Design constraints (ISSUE 7 tentpole):

* **Zero overhead when disabled.**  The process-global tracer defaults to
  a ``NullSink``; ``Tracer.enabled`` is a plain attribute read, so a hot
  call site guards with ``if tr.enabled:`` and pays one branch — no attr
  dicts are built, no records allocated.  ``tr.event(...)`` /
  ``tr.span(...)`` are also safe to call unguarded (they early-return /
  return a shared no-op span), but hot loops should guard so the kwargs
  dict is never constructed.
* **Single-threaded span nesting.**  The serving loop and scheduler run
  on one thread; nesting is a plain list stack.  Each record carries a
  monotonically increasing ``seq`` plus ``span``/``parent`` ids so
  ordering and nesting reconstruct offline.
* **JSONL export.**  One JSON object per line; ``read_jsonl`` is the
  inverse.  Span records are emitted at span *exit* (so a child's record
  precedes its parent's) carrying ``ts`` (entry time) and ``dur_s``.

Record schema (see ROADMAP §Observability for the full event-name list —
serving admission emits ``serve/admit`` per admitted request and, under
chunked prefill, one ``serve/prefill_start`` plus one
``serve/prefill_chunk`` per fixed-shape chunk dispatch):

    {"type": "span"|"event", "name": str, "seq": int, "ts": float,
     "span": int|None, "parent": int|None, "dur_s": float (spans only),
     "attrs": {...}}

No imports from the rest of ``repro`` — core/kernels/serving import
*this* module, never the reverse.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, TextIO


def _jsonable(obj: Any) -> Any:
    """Fallback encoder: numpy scalars -> python, array-likes -> lists,
    anything else -> repr."""
    try:
        return obj.item()          # numpy scalar / 0-d array
    except ValueError:             # size > 1 array: keep the values
        try:
            return obj.tolist()
        except Exception:
            return repr(obj)
    except AttributeError:
        return repr(obj)


class NullSink:
    """The default: tracing off.  ``enabled`` is False and ``emit`` is
    unreachable from guarded call sites."""
    enabled = False

    def emit(self, record: dict) -> None:  # pragma: no cover - guarded off
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class ListSink:
    """In-memory sink for tests."""
    enabled = True

    def __init__(self) -> None:
        self.records: list[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line, append-as-you-go (a crash keeps the
    prefix).  Non-finite floats are JSON-sanitised to ``None`` so the
    file stays parseable by strict readers."""
    enabled = True

    def __init__(self, path: str):
        self.path = str(path)
        self._fh: TextIO = open(self.path, "w", encoding="utf-8")

    def emit(self, record: dict) -> None:
        self._fh.write(json.dumps(_sanitize(record), default=_jsonable))
        self._fh.write("\n")

    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


def _sanitize(obj: Any) -> Any:
    """Replace non-finite floats with None, recursively (strict JSON has
    no Infinity/NaN literals; plan predictions can legitimately be inf)."""
    if isinstance(obj, float):
        return obj if obj == obj and obj not in (float("inf"), float("-inf")) else None
    if isinstance(obj, dict):
        return {k: _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    return obj


def read_jsonl(path: str) -> list[dict]:
    with open(path, "r", encoding="utf-8") as fh:
        return [json.loads(line) for line in fh if line.strip()]


class _NullSpan:
    """Shared no-op context manager returned when tracing is off."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """Emitted as ONE record at exit; ``set`` adds attrs mid-flight."""
    __slots__ = ("_tracer", "name", "attrs", "span_id", "parent_id", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = tracer._new_id()
        self.parent_id: int | None = None
        self._t0 = 0.0

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        tr = self._tracer
        self.parent_id = tr._stack[-1] if tr._stack else None
        tr._stack.append(self.span_id)
        self._t0 = tr.clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        dur = tr.clock() - self._t0
        if tr._stack and tr._stack[-1] == self.span_id:
            tr._stack.pop()
        tr._emit({
            "type": "span", "name": self.name, "span": self.span_id,
            "parent": self.parent_id, "ts": self._t0, "dur_s": dur,
            "attrs": self.attrs,
        })
        return False


class Tracer:
    """Span/event frontend over a sink.  ``Tracer()`` is disabled (NullSink)."""

    def __init__(self, sink=None, clock: Callable[[], float] = time.perf_counter):
        self.sink = sink if sink is not None else NullSink()
        self.enabled: bool = self.sink.enabled
        self.clock = clock
        self._seq = 0
        self._next = 0
        self._stack: list[int] = []

    def _new_id(self) -> int:
        self._next += 1
        return self._next

    def _emit(self, record: dict) -> None:
        record["seq"] = self._seq
        self._seq += 1
        self.sink.emit(record)

    def event(self, name: str, **attrs) -> None:
        """Point-in-time record, parented to the innermost open span."""
        if not self.enabled:
            return
        self._emit({
            "type": "event", "name": name, "span": None,
            "parent": self._stack[-1] if self._stack else None,
            "ts": self.clock(), "attrs": attrs,
        })

    def span(self, name: str, **attrs):
        """Context manager; a shared no-op when disabled."""
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def flush(self) -> None:
        self.sink.flush()

    def close(self) -> None:
        self.sink.close()
        self.enabled = False
        self.sink = NullSink()


#: process-global tracer; NullSink by default so instrumented hot paths
#: pay one ``enabled`` branch until someone calls configure()/set_tracer()
_GLOBAL = Tracer()


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` globally; returns the previous one (so callers
    can restore it — tests and --trace both do)."""
    global _GLOBAL
    old = _GLOBAL
    _GLOBAL = tracer
    return old


def configure(path: str | None = None, sink=None) -> Tracer:
    """Install a global tracer: JSONL to ``path``, an explicit ``sink``,
    or (neither) the disabled default."""
    if path is not None and sink is not None:
        raise ValueError("pass path or sink, not both")
    if path is not None:
        sink = JsonlSink(path)
    tracer = Tracer(sink)
    set_tracer(tracer)
    return tracer
