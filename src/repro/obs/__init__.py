"""Runtime observability: structured tracing, serving metrics, profiler.

Three layers, smallest dependency surface first:

* ``obs.trace`` — span/event tracer with a ``NullSink`` default.  Hot
  paths (the per-tick serving loop, plan dispatch) pay exactly one
  ``tracer.enabled`` branch when tracing is off; when on, records stream
  to JSONL for offline analysis.  No repro-internal imports.
* ``obs.metrics`` — plain-Python counters / gauges / bounded histograms
  for the serving path.  Never device allocations: the zero-allocation
  serving invariant (StatePool.buffers_built == capacity) must hold with
  metrics enabled, so everything here is host ints and deques.
* ``obs.profile`` — measured kernel profiler: warmup-aware,
  ``block_until_ready``-synced sweeps over each family's viable tiling
  surface, persisted per device kind + VMEM budget, consumable by
  ``Scheduler.calibrate(profile=...)``.  Imports core/plans lazily so
  ``repro.obs`` stays importable without pulling in kernels.

ROADMAP §Observability documents the event schema and profile key.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, Metrics
from repro.obs.trace import (
    JsonlSink,
    ListSink,
    NullSink,
    Tracer,
    configure,
    get_tracer,
    read_jsonl,
    set_tracer,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "Metrics",
    "JsonlSink", "ListSink", "NullSink", "Tracer",
    "configure", "get_tracer", "read_jsonl", "set_tracer",
]
