"""Measured kernel profiler — the on-device half of MobiRNN's tuning loop.

The paper's central claim is that tiling/plan choices must be tuned *per
device, per load*; our ``choose_batch_block`` / ``choose_chunk`` tables
are analytic.  This module closes the loop:

* ``profile_families`` sweeps the viable tiling surface each family
  publishes through ``Family.profile_hook`` (core/plans.py) — jitted
  dispatches at concrete ``(block_b, time_chunk)`` / chunk points —
  timing each with ``time_fn`` (untimed warmups absorb JIT compile,
  ``block_until_ready`` syncs async dispatch, min-over-repeats rejects
  scheduler noise).
* The result persists as a ``DeviceProfile`` keyed on
  ``platform:device_kind`` + the VMEM budget it was swept under — a
  profile measured on one device class never silently seeds another.
* ``Scheduler.calibrate(profile=DeviceProfile.best_latencies(...))``
  seeds plan base latencies from the measurement instead of cold
  analytic estimates (core/scheduler.py).
* ``model_vs_measured`` joins each measured point against the analytic
  roofline (``analysis.lstm_seq_stream_costs`` /
  ``analysis.wkv6_stream_costs``) and emits a divergence ratio per
  point, flagging those beyond a threshold — the validation step Rezk et
  al.'s survey calls for.  NB: under interpret-mode Pallas on CPU the
  ratio is uniformly huge (the model prices a TPU roofline); the ratio
  is a *relative* diagnostic there, which is why the CI smoke asserts
  finiteness, not magnitude.

core/plans is imported lazily so ``repro.obs`` itself stays free of
kernel imports.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from typing import Any, Callable, Mapping


def device_kind() -> str:
    """Profile key half 1: ``platform:device_kind`` of the default device."""
    import jax

    d = jax.devices()[0]
    return f"{d.platform}:{d.device_kind}"


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-``repeats`` wall time of ``fn(*args)``, after ``warmup``
    untimed calls (JIT compile + caches) and with ``block_until_ready``
    inside the timed region — the same discipline benchmarks/run.py uses."""
    import jax

    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class ProfilePoint:
    """One measured point on a family's viable tiling surface."""
    family: str
    plan: str
    point: dict[str, Any]            # tiling coordinates, JSON-able
    measured_s: float
    model_s: float | None = None     # analytic roofline seconds, if modeled

    @property
    def ratio(self) -> float | None:
        """measured / modeled — the divergence the report flags."""
        if self.model_s is None or self.model_s <= 0:
            return None
        return self.measured_s / self.model_s

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "ProfilePoint":
        return cls(**obj)


@dataclasses.dataclass
class DeviceProfile:
    """A persisted sweep: every point measured on ONE device under ONE
    VMEM budget.  ``key`` is the identity ``calibrate`` callers should
    match before trusting the numbers."""
    device_kind: str
    vmem_budget: int
    points: list[ProfilePoint]
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> str:
        return f"{self.device_kind}/vmem{self.vmem_budget}"

    def families(self) -> list[str]:
        return sorted({p.family for p in self.points})

    def best_latencies(self, rename: Mapping[str, str] | None = None
                       ) -> dict[str, float]:
        """Per-plan best measured seconds — the mapping
        ``Scheduler.calibrate(profile=...)`` consumes.  ``rename`` maps a
        family plan name to the scheduler's registered name (e.g.
        ``{"fused_seq": "accel_seq", "chunked_scan": "accel_wkv"}``)."""
        out: dict[str, float] = {}
        for p in self.points:
            name = p.plan if rename is None else rename.get(p.plan, p.plan)
            if name not in out or p.measured_s < out[name]:
                out[name] = p.measured_s
        return out

    def to_json(self) -> dict:
        return {"device_kind": self.device_kind,
                "vmem_budget": self.vmem_budget,
                "meta": self.meta,
                "points": [p.to_json() for p in self.points]}

    @classmethod
    def from_json(cls, obj: dict) -> "DeviceProfile":
        return cls(device_kind=obj["device_kind"],
                   vmem_budget=int(obj["vmem_budget"]),
                   points=[ProfilePoint.from_json(p) for p in obj["points"]],
                   meta=obj.get("meta", {}))

    def save(self, path: str) -> str:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "DeviceProfile":
        with open(path, "r", encoding="utf-8") as fh:
            return cls.from_json(json.load(fh))


def profile_families(families: tuple[str, ...] = ("lstm", "rwkv6",
                                                  "mamba"), *,
                     vmem_budget: int | None = None, repeats: int = 2,
                     warmup: int = 1, max_points: int = 4,
                     hook_kwargs: Mapping[str, dict] | None = None
                     ) -> DeviceProfile:
    """Sweep each family's profile hook and measure every candidate.

    ``hook_kwargs`` passes per-family shape overrides through to the hook
    (e.g. ``{"lstm": {"seq_len": 16}}`` for a fast CI smoke).  Emits a
    ``profile/point`` trace event per measurement when tracing is on.
    """
    from repro.core import factorization as fz
    from repro.core import plans as plans_lib
    from repro.obs import trace as trace_lib

    budget = fz.DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    tr = trace_lib.get_tracer()
    points: list[ProfilePoint] = []
    for name in families:
        fam = plans_lib.get_family(name)
        if fam.profile_hook is None:
            raise ValueError(f"family {name!r} registers no profile_hook")
        kwargs = dict((hook_kwargs or {}).get(name, {}))
        cands = fam.profile_hook(vmem_budget=budget, max_points=max_points,
                                 **kwargs)
        for c in cands:
            measured = time_fn(c.fn, *c.args, repeats=repeats, warmup=warmup)
            pt = ProfilePoint(c.family, c.plan, dict(c.point), measured,
                              c.model_s)
            points.append(pt)
            if tr.enabled:
                tr.event("profile/point", family=pt.family, plan=pt.plan,
                         measured_s=pt.measured_s, model_s=pt.model_s,
                         **pt.point)
    return DeviceProfile(device_kind(), int(budget), points)


def model_vs_measured(profile: DeviceProfile,
                      threshold: float | None = None) -> list[dict]:
    """One row per profiled point: measured, modeled, and their ratio.

    ``threshold`` (>1) flags rows whose ratio falls outside
    ``[1/threshold, threshold]`` as ``diverged`` — the policy knob ROADMAP
    §Observability documents.  Rows without an analytic model carry
    ``ratio=None`` and are never flagged.
    """
    if threshold is not None and threshold <= 1:
        raise ValueError("threshold must be > 1 (a symmetric band)")
    rows = []
    for p in profile.points:
        r = p.ratio
        diverged = (threshold is not None and r is not None
                    and not (1.0 / threshold <= r <= threshold))
        rows.append({"family": p.family, "plan": p.plan, "point": p.point,
                     "measured_s": p.measured_s, "model_s": p.model_s,
                     "ratio": r, "finite": r is not None and math.isfinite(r),
                     "diverged": diverged})
    return rows
