"""Single-token flash-decode attention Pallas TPU kernel.

The serving hot spot: one new query token attends over a long KV cache.
MobiRNN's factorization rule applied to decode: the cache is streamed
through VMEM in coarse blocks of `block_s` positions (few large work units),
with the online-softmax running statistics (m, l, acc) held in VMEM scratch
across the sequential cache-block grid dimension — no (B,H,S) score tensor
ever exists in HBM.

GQA is handled in the index map: query head h reads kv head h // group.

Grid: (B, Hq, S/block_s), cache-block dim innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr, acc_scr,
            *, scale: float, block_s: int):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (dk,)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_s, dk)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (block_s, dv)
    length = len_ref[0, 0]

    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
    valid = pos < length
    # zero invalid rows: padded partial blocks are NaN-poisoned in interpret
    # mode and 0 * NaN would otherwise leak into the accumulator
    v = jnp.where(valid[:, None], v, 0.0)
    scores = (k @ q) * scale                     # (block_s,)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(scores))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                  # (block_s,)
    l_new = l_scr[0, 0] * alpha + jnp.sum(p)
    acc_new = acc_scr[0] * alpha + p @ v         # (dv,)
    m_scr[0, 0] = m_new
    l_scr[0, 0] = l_new
    acc_scr[0] = acc_new

    @pl.when(s == ns - 1)
    def _final():
        o_ref[0, 0] = (acc_new / l_new).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "interpret", "scale"))
def decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                lengths: jax.Array, *, scale: float | None = None,
                block_s: int = 128, interpret: bool = True) -> jax.Array:
    """q: (B, Hq, dk); caches: (B, S, Hkv, dk); lengths: (B,) int32.

    Returns (B, Hq, dk) attention outputs for the single new token.
    """
    B, Hq, dk = q.shape
    _, S, Hkv, dv = v_cache.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = dk ** -0.5 if scale is None else scale
    bs = min(block_s, S)
    ns = pl.cdiv(S, bs)
    len2 = lengths.reshape(B, 1).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_s=bs),
        grid=(B, Hq, ns),
        in_specs=[
            pl.BlockSpec((1, 1, dk), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, bs, 1, dk), lambda b, h, s: (b, s, h // group, 0)),
            pl.BlockSpec((1, bs, 1, dv), lambda b, h, s: (b, s, h // group, 0)),
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, len2)
    return out
