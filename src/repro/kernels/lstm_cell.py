"""Fused LSTM-cell Pallas TPU kernel — the paper's core optimization.

MobiRNN §3.2/§3.3: combine the four gate matmuls into ONE coarse work unit
([x,h] @ W_fused) and fuse the point-wise gate non-linearities behind it so
no intermediate gate tensor round-trips through backing memory.  On TPU this
becomes a single `pallas_call`: the gate matmul runs on the MXU from VMEM
tiles, and the sigmoid/tanh/c/h updates happen in VREGs before the (c', h')
blocks are written back — one HBM round-trip per cell instead of ~10.

Block decomposition follows core/factorization.choose_block: grid over
(batch tiles x hidden tiles), the reduction dim (D+H) is kept whole per block
(it is the paper's "pack many vector products into one work unit" rule; for
the model sizes this framework serves, (D+H) x 4*bh tiles fit VMEM).

Weight layout: W is pre-reshaped by the wrapper to (D+H, 4, H) so one hidden
tile pulls the matching column slice of ALL FOUR gates in a single block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xh_ref, w_ref, b_ref, c_ref, c_out_ref, h_out_ref):
    xh = xh_ref[...]                       # (bm, K)
    w = w_ref[...]                         # (K, 4, bh)
    b = b_ref[...]                         # (4, bh)
    bm = xh.shape[0]
    bh = w.shape[-1]
    # one coarse MXU work unit: all four gates of this hidden tile at once
    gates = jax.lax.dot_general(
        xh, w.reshape(w.shape[0], 4 * bh),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(bm, 4, bh) + b[None].astype(jnp.float32)
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    g = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c = c_ref[...].astype(jnp.float32)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_h", "interpret"),
)
def _lstm_cell_call(w: jax.Array, b: jax.Array, x: jax.Array, c: jax.Array,
                    h: jax.Array, block_b: int, block_h: int,
                    interpret: bool) -> tuple[jax.Array, jax.Array]:
    B, D = x.shape
    H = c.shape[-1]
    K = D + H
    xh = jnp.concatenate([x, h], axis=-1)
    w3 = w.reshape(K, 4, H)
    b2 = b.reshape(4, H)
    bm = min(block_b, B)
    bh = min(block_h, H)
    grid = (pl.cdiv(B, bm), pl.cdiv(H, bh))
    out_struct = jax.ShapeDtypeStruct((B, H), c.dtype)
    c_new, h_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda ib, jh: (ib, 0)),
            pl.BlockSpec((K, 4, bh), lambda ib, jh: (0, 0, jh)),
            pl.BlockSpec((4, bh), lambda ib, jh: (0, jh)),
            pl.BlockSpec((bm, bh), lambda ib, jh: (ib, jh)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bh), lambda ib, jh: (ib, jh)),
            pl.BlockSpec((bm, bh), lambda ib, jh: (ib, jh)),
        ],
        out_shape=[out_struct, out_struct],
        interpret=interpret,
    )(xh, w3, b2, c)
    return c_new, h_new


# ---------------------------------------------------------------------------
# Differentiable entry point: pallas_call has no VJP rule, so the backward
# differentiates the per-cell jnp oracle (kernels/ref.lstm_cell — identical
# math), making the per-cell plan a real TRAINING choice.  Per cell that is
# one oracle-VJP; composed over the scan it is the O(T*L) baseline the
# sequence-resident reverse sweep (kernels/lstm_seq_bwd.py) coarsens away.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _lstm_cell(w, b, x, c, h, block_b, block_h, interpret):
    return _lstm_cell_call(w, b, x, c, h, block_b, block_h, interpret)


def _lstm_cell_fwd(w, b, x, c, h, block_b, block_h, interpret):
    out = _lstm_cell_call(w, b, x, c, h, block_b, block_h, interpret)
    return out, (w, b, x, c, h)


def _lstm_cell_bwd(block_b, block_h, interpret, residuals, cotangents):
    from repro.kernels import ref

    _, vjp = jax.vjp(ref.lstm_cell, *residuals)
    return vjp(cotangents)


_lstm_cell.defvjp(_lstm_cell_fwd, _lstm_cell_bwd)


def lstm_cell(w: jax.Array, b: jax.Array, x: jax.Array, c: jax.Array,
              h: jax.Array, *, block_b: int = 128, block_h: int = 128,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused cell step.  w: (D+H, 4H) gate order (i,f,g,o); x: (B, D);
    c, h: (B, H).  Returns (c', h')."""
    B, D = x.shape
    H = c.shape[-1]
    assert w.shape == (D + H, 4 * H), (w.shape, D + H, H)
    return _lstm_cell(w, b, x, c, h, block_b, block_h, interpret)
