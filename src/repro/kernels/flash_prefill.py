"""Blocked causal (flash) prefill attention Pallas TPU kernel.

The prefill compute hot-spot.  MobiRNN's coarse-factorization rule sets the
block shapes (few, large, MXU-aligned VMEM tiles); the causal structure
prunes work at BLOCK granularity: a kv block entirely in the future of a
query block contributes nothing and its math is skipped with ``pl.when``
(the grid still visits it, but no FLOPs are issued — the TPU analogue of
not launching the work unit at all).  Sliding windows prune past blocks the
same way.  Online-softmax statistics live in VMEM scratch across the
sequential kv-block grid dimension.

Grid: (B, Hq, nq, nk), kv-block dim innermost.  GQA via index_map
(query head h reads kv head h // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, q_block: int, k_block: int, window: int,
            seq_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * q_block
    q_end = q_start + q_block - 1
    k_start = kj * k_block
    k_end = k_start + k_block - 1

    # causal block skip: kv block entirely in the future -> no work unit
    live = k_start <= q_end
    if window:
        # window skip: kv block entirely before the window of every query
        live = jnp.logical_and(live, k_end >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (qb, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (kb, dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (kb, dh)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (q_block, k_block), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (q_block, k_block), 1)
        mask = (qp >= kp) & (kp < seq_len)
        if window:
            mask &= (qp - kp) < window
        # padded partial-block tails are NaN-poisoned in interpret mode;
        # zero v there so 0*NaN can't leak into the accumulator
        kvalid = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                    (k_block,), 0) < seq_len
        v = jnp.where(kvalid[:, None], v, 0.0)
        s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot(p, v,
                                      preferred_element_type=jnp.float32))
        m_scr[:, 0] = m_new

    @pl.when(kj == nk - 1)
    def _final():
        l = jnp.maximum(l_scr[:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "q_block", "k_block", "window", "scale", "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0, scale: float | None = None,
                  q_block: int = 128, k_block: int = 128,
                  interpret: bool = True) -> jax.Array:
    """q: (B, S, Hq, dh); k, v: (B, S, Hkv, dh).  Returns (B, S, Hq, dh).

    Causal; window > 0 additionally restricts attention to the last
    `window` positions."""
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = dh ** -0.5 if scale is None else scale
    qb = min(q_block, S)
    kb = min(k_block, S)
    nq, nk = pl.cdiv(S, qb), pl.cdiv(S, kb)
    # layout: (B, H, S, dh) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, q_block=qb, k_block=kb,
                          window=window, seq_len=S),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, qb, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, kb, dh),
                         lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, kb, dh),
                         lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
