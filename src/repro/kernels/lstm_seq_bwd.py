"""Sequence-resident fused LSTM BACKWARD: the whole BPTT sweep in ONE
``pallas_call``.

This is the other half of kernels/lstm_seq.py — MobiRNN's coarsening lesson
applied to training.  The naive custom-VJP fallback replays the entire
forward through the jnp oracle and lets autodiff unroll T x L cell
backwards, so training with the "fast" plan used to be dispatch-bound again
exactly where the forward had stopped being.  Here the reverse-time loop
runs INSIDE the kernel:

* grid over batch tiles (batch rows stay independent in the backward);
* ``fori_loop`` over reversed time; per step, layers unwind top-down;
* gates are RECOMPUTED from the stored (T, L, bm, H) f32 trajectory
  residuals (the lstm_seq._seq_traj_kernel contract) — same matmuls as the
  forward, so the recomputed activations are bit-identical and the
  gradients exact-math;
* ``dw``/``db`` accumulate in f32 VMEM scratch that persists across grid
  steps (batch tiles), written to the outputs once on the last tile;
* the ``(dc, dh)`` time-carries live in VMEM scratch and never round-trip
  HBM between steps — the preallocation bound, mirrored in reverse.

Cotangent contract: inputs are the final-state cotangents ``(dc, dh)``
each (L, B, H); outputs are ``(dw, db, dx)`` in the parameter/input dtypes.
VMEM sizing: lstm_seq.working_set_bytes(mode="bwd"); when
choose_batch_block(mode="bwd") returns None the custom_vjp in lstm_seq.py
falls back to the oracle instead of dispatching this kernel.

Time streaming (``time_chunk=tc``): the whole-T-resident layout holds two
(T, L, bm, H) f32 trajectories in VMEM, which dominates the backward
working set at long T.  The chunked layout keeps x and both trajectories
in HBM and streams them through double-buffered VMEM windows in REVERSE
chunk order — chunk k-1 prefetches while chunk k unwinds — with a
(tc+1)-row trajectory window so the pre-step state of a chunk's first
timestep (the last row of the previous chunk) is always present; dx
streams out through two staging buffers.  The f32 dw/db accumulators and
the (dc, dh) carries stay VMEM-resident across chunks AND batch tiles, so
residency is O(tc) in T.  Chunking changes data movement only — the
unwind math is identical step-for-step, so gradients are bit-identical to
the unchunked sweep (tests/test_lstm_seq.py asserts it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _unwind_step(x_t, c_t, h_t, c_prev_all, h_prev_all, w_ref, b_ref,
                 dw_scr, db_scr, dc_scr, dh_scr,
                 *, n_layers: int, p_width: int, s_ref=None):
    """Unwind ALL layers of one timestep, updating the (dc, dh) carries and
    the dw/db accumulators in place; returns this step's dx row (bm, P).

    Inputs are the (already masked) forward values at step t: x_t (bm, P),
    post-step states c_t/h_t (L, bm, H) and pre-step states
    c_prev_all/h_prev_all (L, bm, H, zeros at t == 0).  Shared by the
    whole-T-resident and time-chunked kernel bodies so the two layouts
    unwind bit-identically.

    ``s_ref`` (optional): (L, 4H) f32 per-channel scales — the int8 path.
    The gate recompute folds the scale into the pre-activations EXACTLY as
    the q8 forward did (bit-identical recompute); the outgoing input/carry
    grads dot ``dgates * s`` against the int8 block (dgates @ (wq*s)^T ==
    (dgates*s) @ wq^T); the dw/db accumulation is unchanged — it is the
    STRAIGHT-THROUGH gradient wrt the DEQUANTIZED weights, accumulated and
    emitted in f32 for the master stack.
    """
    hidden = dc_scr.shape[-1]
    dinp = jnp.zeros_like(x_t)                           # from layer above
    for layer in range(n_layers - 1, -1, -1):            # static unroll
        w = w_ref[layer].astype(F32)                     # (P+H, 4H)
        scale = None if s_ref is None else s_ref[layer].astype(F32)
        c_prev = c_prev_all[layer]
        h_prev = h_prev_all[layer]
        if layer == 0:
            inp = x_t
        else:
            below = h_t[layer - 1]
            inp = below if p_width == hidden else \
                jnp.pad(below, ((0, 0), (0, p_width - hidden)))
        # recompute this cell's gates — same two matmuls as the forward
        gates = (
            jax.lax.dot_general(inp, w[:p_width],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32)
            + jax.lax.dot_general(h_prev, w[p_width:],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=F32))
        if scale is not None:
            gates = gates * scale                        # fold channel scale
        gates = gates + b_ref[layer].astype(F32)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        si, sf, so = (jax.nn.sigmoid(i), jax.nn.sigmoid(f),
                      jax.nn.sigmoid(o))
        tg = jnp.tanh(g)
        tc_ = jnp.tanh(c_t[layer])
        # incoming grads: time-carry + the layer above's input grad
        dh = dh_scr[layer] + dinp[:, :hidden]
        dc = dc_scr[layer] + dh * so * (1.0 - tc_ * tc_)
        dgates = jnp.concatenate([
            dc * tg * si * (1.0 - si),                   # d pre-i
            dc * c_prev * sf * (1.0 - sf),               # d pre-f
            dc * si * (1.0 - tg * tg),                   # d pre-g
            dh * tc_ * so * (1.0 - so),                  # d pre-o
        ], axis=-1)                                      # (bm, 4H)
        # parameter grads: [inp | h_prev]^T @ dgates, f32 accumulation
        dw_rows = jnp.concatenate([
            jax.lax.dot_general(inp, dgates, (((0,), (0,)), ((), ())),
                                preferred_element_type=F32),
            jax.lax.dot_general(h_prev, dgates,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=F32),
        ], axis=0)                                       # (P+H, 4H)
        dw_scr[layer] = dw_scr[layer] + dw_rows
        db_scr[layer] = db_scr[layer] + jnp.sum(dgates, axis=0)
        # outgoing grads: recurrence carry + the layer below / input —
        # through the DEQUANTIZED weights on the q8 path
        dg_w = dgates if scale is None else dgates * scale
        dh_scr[layer] = jax.lax.dot_general(
            dg_w, w[p_width:], (((1,), (1,)), ((), ())),
            preferred_element_type=F32)                  # -> h_{t-1}[layer]
        dc_scr[layer] = dc * sf                          # -> c_{t-1}[layer]
        dinp = jax.lax.dot_general(
            dg_w, w[:p_width], (((1,), (1,)), ((), ())),
            preferred_element_type=F32)                  # (bm, P)
    return dinp


def _seq_bwd_kernel(x_ref, w_ref, b_ref, ct_ref, ht_ref, dcf_ref, dhf_ref,
                    dw_ref, db_ref, dx_ref,
                    dw_scr, db_scr, dc_scr, dh_scr,
                    *, n_layers: int, seq_len: int, p_width: int,
                    n_tiles: int, batch: int, s_ref=None):
    """One batch tile unwinds the whole (T x L) recurrence from VMEM.

    x_ref: (T, bm, P); w_ref: (L, P+H, 4H); b_ref: (L, 4H);
    ct_ref/ht_ref: (T, L, bm, H) f32 post-step state trajectories;
    dcf_ref/dhf_ref: (L, bm, H) final-state cotangents.
    dw_scr/db_scr are f32 accumulators shared across ALL grid steps (scratch
    persists between batch tiles); dc_scr/dh_scr carry the per-tile
    reverse-time gradient state.

    Unlike the forward — where a non-dividing final tile's out-of-range
    rows just compute garbage that the output re-tiling drops — here those
    rows would flow into the SHARED dw/db accumulators, so every load is
    masked to the valid batch rows of this tile.
    """
    bm = dc_scr.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    valid = (pl.program_id(0) * bm + rows) < batch       # (bm, 1)

    def mask2(a):                                        # (bm, X)
        return jnp.where(valid, a, 0.0)

    def mask3(a):                                        # (L, bm, X)
        return jnp.where(valid[None], a, 0.0)

    @pl.when(pl.program_id(0) == 0)
    def _zero_accumulators():
        dw_scr[...] = jnp.zeros_like(dw_scr)
        db_scr[...] = jnp.zeros_like(db_scr)

    dc_scr[...] = mask3(dcf_ref[...].astype(F32))
    dh_scr[...] = mask3(dhf_ref[...].astype(F32))

    def step(rev_t, carry):
        t = seq_len - 1 - rev_t
        x_t = mask2(x_ref[pl.ds(t, 1)][0].astype(F32))   # (bm, P)
        c_t = mask3(ct_ref[pl.ds(t, 1)][0])              # (L, bm, H)
        h_t = mask3(ht_ref[pl.ds(t, 1)][0])
        # pre-step state: the previous trajectory row, zeros at t == 0
        # (clamped read + where keeps the access in bounds under tracing)
        tm1 = jnp.maximum(t - 1, 0)
        alive = (t > 0).astype(F32)
        c_prev_all = mask3(ct_ref[pl.ds(tm1, 1)][0]) * alive
        h_prev_all = mask3(ht_ref[pl.ds(tm1, 1)][0]) * alive

        dinp = _unwind_step(x_t, c_t, h_t, c_prev_all, h_prev_all,
                            w_ref, b_ref, dw_scr, db_scr, dc_scr, dh_scr,
                            n_layers=n_layers, p_width=p_width, s_ref=s_ref)
        dx_ref[pl.ds(t, 1)] = dinp[None].astype(dx_ref.dtype)
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)

    @pl.when(pl.program_id(0) == n_tiles - 1)
    def _emit_param_grads():
        dw_ref[...] = dw_scr[...].astype(dw_ref.dtype)
        db_ref[...] = db_scr[...].astype(db_ref.dtype)


def _seq_bwd_q8_kernel(x_ref, w_ref, s_ref, b_ref, ct_ref, ht_ref, dcf_ref,
                       dhf_ref, dw_ref, db_ref, dx_ref,
                       dw_scr, db_scr, dc_scr, dh_scr,
                       *, n_layers: int, seq_len: int, p_width: int,
                       n_tiles: int, batch: int):
    """Int8-weight reverse sweep: the same unwind with the (L, 4H) f32
    scales as an extra input and int8 weights VMEM-resident; dw/db emit in
    f32 (straight-through master-weight gradients)."""
    _seq_bwd_kernel(x_ref, w_ref, b_ref, ct_ref, ht_ref, dcf_ref, dhf_ref,
                    dw_ref, db_ref, dx_ref, dw_scr, db_scr, dc_scr, dh_scr,
                    n_layers=n_layers, seq_len=seq_len, p_width=p_width,
                    n_tiles=n_tiles, batch=batch, s_ref=s_ref)


def _seq_bwd_chunked_kernel(x_hbm, w_ref, b_ref, ct_hbm, ht_hbm,
                            dcf_ref, dhf_ref,
                            dw_ref, db_ref, dx_hbm,
                            xbuf, ctb, htb, dxb,
                            dw_scr, db_scr, dc_scr, dh_scr,
                            xsem, csem, hsem, osem,
                            *, n_layers: int, seq_len: int, p_width: int,
                            tc: int, tw: int, nc: int, n_tiles: int,
                            batch: int, s_ref=None):
    """Time-chunked reverse sweep: the same BPTT unwind, but x and the two
    trajectories stream through double-buffered VMEM windows in REVERSE
    chunk order (chunk k-1 prefetches while chunk k computes) and dx streams
    out through two staging buffers.

    x_hbm: (T, Bp, P); ct_hbm/ht_hbm: (T, L, Bp, H) f32; dx_hbm:
    (nc*tc, Bp, P) time-padded (wrapper slices [:T]).  The trajectory
    window is ``tw = tc+1`` rows (tc when nc == 1) starting one row BEFORE
    the chunk so the pre-step state of the chunk's first timestep — the
    carry crossing the chunk boundary — comes from the same residuals the
    unchunked kernel reads, bit-identically.  Copy starts are clamped so
    the static-size windows stay in bounds at the ends; the masked dw/db
    accumulation is unchanged (batch padding rows never reach the shared
    accumulators).
    """
    bm = dc_scr.shape[1]
    ib = pl.program_id(0)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    valid = (ib * bm + rows) < batch                     # (bm, 1)

    def mask2(a):                                        # (bm, X)
        return jnp.where(valid, a, 0.0)

    def mask3(a):                                        # (L, bm, X)
        return jnp.where(valid[None], a, 0.0)

    def x_src(k):
        return jnp.minimum(k * tc, seq_len - tc)

    def t_src(k):
        return jnp.minimum(jnp.maximum(k * tc - 1, 0), seq_len - tw)

    def dma_x(slot, k):
        return pltpu.make_async_copy(
            x_hbm.at[pl.ds(x_src(k), tc), pl.ds(ib * bm, bm)],
            xbuf.at[slot], xsem.at[slot])

    def dma_traj(hbm, buf, sem, slot, k):
        return pltpu.make_async_copy(
            hbm.at[pl.ds(t_src(k), tw), :, pl.ds(ib * bm, bm)],
            buf.at[slot], sem.at[slot])

    def dma_dx(slot, k):
        return pltpu.make_async_copy(
            dxb.at[slot],
            dx_hbm.at[pl.ds(k * tc, tc), pl.ds(ib * bm, bm)],
            osem.at[slot])

    def start_in(slot, k):
        dma_x(slot, k).start()
        dma_traj(ct_hbm, ctb, csem, slot, k).start()
        dma_traj(ht_hbm, htb, hsem, slot, k).start()

    def wait_in(slot, k):
        dma_x(slot, k).wait()
        dma_traj(ct_hbm, ctb, csem, slot, k).wait()
        dma_traj(ht_hbm, htb, hsem, slot, k).wait()

    @pl.when(ib == 0)
    def _zero_accumulators():
        dw_scr[...] = jnp.zeros_like(dw_scr)
        db_scr[...] = jnp.zeros_like(db_scr)

    dc_scr[...] = mask3(dcf_ref[...].astype(F32))
    dh_scr[...] = mask3(dhf_ref[...].astype(F32))

    start_in(jax.lax.rem(nc - 1, 2), nc - 1)             # warm-up (last)

    def chunk(rev_k, carry):
        k = nc - 1 - rev_k
        slot = jax.lax.rem(k, 2)

        @pl.when(k >= 1)                                 # reverse prefetch
        def _prefetch():
            start_in(jax.lax.rem(k - 1, 2), k - 1)

        wait_in(slot, k)
        # the dx staging slot's previous flight (chunk k+2) must land
        # before this chunk overwrites it
        @pl.when(k + 2 < nc)
        def _reclaim():
            dma_dx(slot, k + 2).wait()

        xs, ts = x_src(k), t_src(k)

        def step(i, c2):
            t = k * tc + (tc - 1 - i)                    # reverse in chunk

            @pl.when(t < seq_len)                        # tail-chunk guard
            def _unwind():
                x_t = mask2(xbuf[slot, t - xs].astype(F32))
                c_t = mask3(ctb[slot, t - ts])           # (L, bm, H)
                h_t = mask3(htb[slot, t - ts])
                lm1 = jnp.maximum(t - 1 - ts, 0)
                alive = (t > 0).astype(F32)
                c_prev_all = mask3(ctb[slot, lm1]) * alive
                h_prev_all = mask3(htb[slot, lm1]) * alive
                dinp = _unwind_step(x_t, c_t, h_t, c_prev_all, h_prev_all,
                                    w_ref, b_ref, dw_scr, db_scr,
                                    dc_scr, dh_scr,
                                    n_layers=n_layers, p_width=p_width,
                                    s_ref=s_ref)
                dxb[slot, t - k * tc] = dinp.astype(dxb.dtype)
            return c2

        jax.lax.fori_loop(0, tc, step, 0)
        dma_dx(slot, k).start()
        return carry

    jax.lax.fori_loop(0, nc, chunk, 0)
    # drain the (at most two) outstanding dx flights: chunks 0 and 1
    dma_dx(0, 0).wait()

    @pl.when(nc >= 2)
    def _drain_prev():
        dma_dx(1, 1).wait()

    @pl.when(ib == n_tiles - 1)
    def _emit_param_grads():
        dw_ref[...] = dw_scr[...].astype(dw_ref.dtype)
        db_ref[...] = db_scr[...].astype(db_ref.dtype)


def _seq_bwd_chunked_q8_kernel(x_hbm, w_ref, s_ref, b_ref, ct_hbm, ht_hbm,
                               dcf_ref, dhf_ref,
                               dw_ref, db_ref, dx_hbm,
                               xbuf, ctb, htb, dxb,
                               dw_scr, db_scr, dc_scr, dh_scr,
                               xsem, csem, hsem, osem,
                               *, n_layers: int, seq_len: int, p_width: int,
                               tc: int, tw: int, nc: int, n_tiles: int,
                               batch: int):
    """Int8-weight streamed reverse sweep (scales with the resident stack)."""
    _seq_bwd_chunked_kernel(x_hbm, w_ref, b_ref, ct_hbm, ht_hbm,
                            dcf_ref, dhf_ref, dw_ref, db_ref, dx_hbm,
                            xbuf, ctb, htb, dxb,
                            dw_scr, db_scr, dc_scr, dh_scr,
                            xsem, csem, hsem, osem,
                            n_layers=n_layers, seq_len=seq_len,
                            p_width=p_width, tc=tc, tw=tw, nc=nc,
                            n_tiles=n_tiles, batch=batch, s_ref=s_ref)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "time_chunk", "interpret"))
def _lstm_seq_bwd_call(w, b, x, ct, ht, dc, dh, block_b: int,
                       time_chunk: int | None, interpret: bool,
                       scales=None):
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    n_tiles = pl.cdiv(B, bm)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    if time_chunk is not None:
        return _lstm_seq_bwd_chunked_call(w, b, xt, ct, ht, dc, dh, bm,
                                          min(time_chunk, T), interpret,
                                          scales=scales)
    if scales is None:
        kernel = functools.partial(_seq_bwd_kernel, n_layers=L, seq_len=T,
                                   p_width=P, n_tiles=n_tiles, batch=B)
        s_in, s_spec = (), ()
        dw_dt, db_dt = w.dtype, b.dtype
    else:
        kernel = functools.partial(_seq_bwd_q8_kernel, n_layers=L,
                                   seq_len=T, p_width=P, n_tiles=n_tiles,
                                   batch=B)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
        dw_dt, db_dt = F32, F32       # straight-through master-weight grads
    dw, db, dxt = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_specs=[
            # constant index maps: the dw/db blocks are revisited by every
            # grid step; the actual cross-tile accumulation happens in the
            # persistent f32 scratch, written out on the last tile
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, dw_dt),
            jax.ShapeDtypeStruct(b.shape, db_dt),
            jax.ShapeDtypeStruct(xt.shape, x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM(w.shape, F32),                    # dw accumulator
            pltpu.VMEM(b.shape, F32),                    # db accumulator
            pltpu.VMEM((L, bm, H), F32),                 # dc time-carry
            pltpu.VMEM((L, bm, H), F32),                 # dh time-carry
        ],
        interpret=interpret,
    )(xt, w, *s_in, b, ct, ht, dc, dh)
    return dw, db, jnp.swapaxes(dxt, 0, 1)               # dx: (B, T, P)


def _lstm_seq_bwd_chunked_call(w, b, xt, ct, ht, dc, dh, bm: int, tc: int,
                               interpret: bool, scales=None):
    """Streamed reverse sweep: x + trajectories in HBM, O(tc) VMEM."""
    from repro.kernels.lstm_seq import _pad_batch

    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    T, B, _ = xt.shape
    n_tiles = pl.cdiv(B, bm)
    Bp = n_tiles * bm
    nc = pl.cdiv(T, tc)
    Tp = nc * tc              # time-padded dx: chunk windows stay disjoint
    tw = tc + 1 if nc > 1 else tc
    xt = _pad_batch(xt, 1, Bp)
    ct = _pad_batch(ct, 2, Bp)
    ht = _pad_batch(ht, 2, Bp)
    dc = _pad_batch(dc, 1, Bp)
    dh = _pad_batch(dh, 1, Bp)
    if scales is None:
        kernel = functools.partial(_seq_bwd_chunked_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, tw=tw,
                                   nc=nc, n_tiles=n_tiles, batch=B)
        s_in, s_spec = (), ()
        dw_dt, db_dt = w.dtype, b.dtype
    else:
        kernel = functools.partial(_seq_bwd_chunked_q8_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, tw=tw,
                                   nc=nc, n_tiles=n_tiles, batch=B)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
        dw_dt, db_dt = F32, F32       # straight-through master-weight grads
    dw, db, dxt = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),        # x streams manually
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),        # c_traj streams
            pl.BlockSpec(memory_space=pltpu.ANY),        # h_traj streams
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_specs=[
            # constant index maps: dw/db accumulate in persistent scratch,
            # written on the last batch tile (same contract as unchunked)
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),        # dx streams out
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, dw_dt),
            jax.ShapeDtypeStruct(b.shape, db_dt),
            jax.ShapeDtypeStruct((Tp, Bp, P), xt.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, tc, bm, P), xt.dtype),        # x double buffer
            pltpu.VMEM((2, tw, L, bm, H), F32),          # c_traj window
            pltpu.VMEM((2, tw, L, bm, H), F32),          # h_traj window
            pltpu.VMEM((2, tc, bm, P), xt.dtype),        # dx staging
            pltpu.VMEM(w.shape, F32),                    # dw accumulator
            pltpu.VMEM(b.shape, F32),                    # db accumulator
            pltpu.VMEM((L, bm, H), F32),                 # dc time-carry
            pltpu.VMEM((L, bm, H), F32),                 # dh time-carry
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(xt, w, *s_in, b, ct, ht, dc, dh)
    return dw, db, jnp.swapaxes(dxt[:T, :B], 0, 1)       # dx: (B, T, P)


def lstm_seq_bwd(w, b, x, ct, ht, dc, dh, *, block_b: int,
                 time_chunk: int | None = None, interpret: bool = True,
                 scales=None):
    """Whole-sequence BPTT in ONE dispatch: (dw, db, dx).

    w: (L, P+H, 4H); b: (L, 4H); x: (B, T, P) padded input;
    ct/ht: (T, L, B, H) f32 trajectories (lstm_seq trajectory contract);
    dc/dh: (L, B, H) cotangents of the final state.  ``block_b`` /
    ``time_chunk`` come from ``lstm_seq.choose_batch_block(mode="bwd")`` —
    callers must not dispatch this kernel when that returns None.
    ``time_chunk=None`` keeps x and both trajectories VMEM-resident;
    ``time_chunk=tc`` streams them in double-buffered reverse-order chunks
    (O(tc) residency, same gradients bit-for-bit).

    ``scales`` (optional): (L, 4H) f32 per-channel scales for the int8 path
    — ``w`` is then the int8 stack the q8 forward ran with, the gate
    recompute folds the scales exactly as the forward did, and (dw, db)
    come back in f32 (straight-through gradients for the master weights).
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, xw = x.shape
    assert xw == P and ct.shape == (T, L, B, H) == ht.shape, \
        (w.shape, x.shape, ct.shape, ht.shape)
    assert dc.shape == (L, B, H) == dh.shape, (dc.shape, dh.shape)
    return _lstm_seq_bwd_call(w, b, x, ct, ht, dc, dh, block_b, time_chunk,
                              interpret, scales=scales)
