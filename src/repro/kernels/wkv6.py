"""RWKV6 chunked-scan Pallas TPU kernel — forward AND fused backward.

This is MobiRNN's coarse work-unit factorization applied to the RWKV6
recurrence: instead of T tiny sequential state updates (the "CUDA-style"
per-step plan, kernels/ref.wkv6_stepwise), the sequence is processed in
chunks of C steps.  Within a chunk everything is a dense MXU-friendly batch
of matmuls on VMEM tiles (one coarse work unit); only the (dk x dv) state
crosses chunk boundaries — it lives in a VMEM scratch accumulator across the
sequential chunk grid dimension, so it never round-trips to HBM during the
scan (the paper's preallocated-state-reuse rule).

Numerical safety: all within-chunk decay exponents are differences
L_a - L_b with a >= b of a running log-decay cumsum, hence <= 0 — no
exp overflow regardless of decay strength (logw <= 0).

Tiling (the lstm_seq contract, via core/tiling): the work unit is a
``(bh_tile, chunk)`` tile of the ``(BH, T)`` surface.  Batch-head rows are
independent, so they tile freely — ``bh_tile`` rows share one grid step,
their f32 states carried together in VMEM scratch (per-row math is
statically unrolled, so results are bit-identical at ANY bh_tile).  The
time axis STREAMS: the r/k/v/logw chunk windows live in HBM
(``pltpu.ANY``) and the kernel moves them through two-slot double-buffered
VMEM windows with async copies, prefetching chunk t+1 while chunk t
computes (pallas_guide §Double Buffering — the same pipeline as
kernels/lstm_seq's input streaming).  The backward streams the SAME windows
plus the dout cotangent and the stored trajectory states in REVERSE chunk
order.  Streaming changes data movement only — the chunk math is untouched,
so streamed kernels are bit-identical to the window-per-BlockSpec layout at
``chunk=1``, ``chunk=T``, and non-dividing ``T``/``BH``
(tests/test_wkv6.py asserts it).

Grid: (ceil(BH/bh_tile), ceil(T/C)); the chunk dimension is innermost
(sequential on TPU), so the scratch state carries correctly.  Non-dividing
T is zero-padded at the END: padded steps have r = k = v = 0 and logw = 0,
which is the IDENTITY on the state (exp(0) = 1 decay, zero k^T v outer
product) and contributes zero output rows that the wrapper slices off — so
padding never changes results, only the grid extent.  Non-dividing BH is
zero-padded the same way: batch-head rows are independent and all-zero
inputs with zero incoming state produce zero outputs and zero state, so the
padded tail rows of the shared f32 state scratch can never leak into real
rows; the wrapper slices them off.

Autodiff: ``pallas_call`` has no VJP rule, so ``wkv6`` wraps the kernel in a
``jax.custom_vjp`` mirroring kernels/lstm_seq.py.  Under differentiation the
forward runs a trajectory-emitting variant (same math, same single dispatch)
that additionally writes the CHUNK-INCOMING states ``s_traj
(BH, nt, dk, dv)`` — the residual the backward recomputes from — and the
backward runs the whole reverse-time sweep in ONE kernel dispatch: the grid
walks chunks in reverse, the streamed windows arrive through the same
two-slot prefetch pipeline (window t+1 of the SWEEP — chunk nt-2-t — in
flight while chunk nt-1-t computes), the state cotangent ``ds`` lives in
VMEM scratch across the sweep, ``du`` accumulates in scratch, and each
chunk's (dr, dk, dv, dlogw) falls out of ``jax.vjp`` of the pure chunk
math re-linearised from the stored incoming state.  ``value_and_grad`` is
exactly 2 Pallas dispatches at any T — O(1) in T, O(BH/bh_tile * T/C) grid
steps (``analysis.count_pallas_grid_steps``).  ``bwd=ORACLE_BWD`` restores
the oracle-VJP fallback (differentiate kernels/ref.wkv6), used when
``choose_chunk(mode="bwd")`` finds no viable chunk.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization, tiling

F32 = jnp.float32

#: ``bwd=`` sentinel: differentiate the pure-jnp oracle instead of running
#: the fused reverse sweep (the principled fallback past the bwd budget).
ORACLE_BWD = 0
#: ``bwd=`` default: ONE reverse-order Pallas dispatch for the whole sweep.
FUSED_BWD = 1


# ---------------------------------------------------------------------------
# VMEM budget — the (bh_tile, chunk) analogue of lstm_seq's
# (block_b, time_chunk), built on the same core/tiling substrate.
# ---------------------------------------------------------------------------
class WkvBlocks(NamedTuple):
    """The chunked-scan kernel's tiling decision: chunk length x BH tile.

    ``chunk`` is the work-unit-coarseness knob of the WKV6 plan — larger C
    means denser MXU matmuls and fewer grid steps (O(T/C)), at the price of
    the (C, C, dk) f32 intra-chunk decay tensor, the dominant VMEM term.
    ``bh_tile`` is the batch axis of the same surface — how many
    independent batch-head rows share one grid step (coarser = fewer grid
    steps, more streamed-window and state bytes per step).

    Presents the family-generic ``core/tiling.TilePlan`` interface:
    ``batch_tile`` is this family's ``bh_tile`` (fused B*H rows),
    ``time_chunk`` its ``chunk`` (this grid always streams time, so it is
    never None)."""
    chunk: int
    bh_tile: int = 1

    @property
    def batch_tile(self) -> int:
        return self.bh_tile

    @property
    def time_chunk(self) -> int:
        return self.chunk


def working_set_bytes(seq_len: int, dk: int, dv: int, chunk: int,
                      dtype_bytes: int = 4, mode: str = "fwd", *,
                      bh_tile: int = 1) -> int:
    """VMEM working set of one (bh_tile, chunk) grid step, per phase.

    ``mode="fwd"`` sizes the inference forward: the two-slot double-buffered
    r/k/v/logw streamed windows + the output tile, u, the s0/s_out blocks,
    the f32 state scratch (all x ``bh_tile`` rows), and the (C, C, dk) f32
    intra-chunk decay tensor plus its (C, C) score matrix — priced once,
    not per row, because the per-row chunk math unrolls sequentially within
    the grid step; it is the term that grows quadratically in C and makes
    the chunk length a real budget decision.

    ``mode="bwd"`` sizes the reverse-sweep dispatch, which strictly
    dominates the trajectory-emitting forward that feeds it: on top of the
    forward set it holds the two-slot streamed chunk-incoming state and
    dout cotangent windows, the mirrored (dr, dk, dv, dlogw) output tiles,
    the ds state-cotangent scratch + ds0/ds_fin blocks, the du accumulator,
    and a second copy of the intra-chunk tensors (the linearised chunk
    recompute keeps forward values live while the cotangent flows back) —
    roughly 3x the forward working set at typical head shapes.
    """
    ws = tiling.WorkingSet(mode)
    C = max(1, min(chunk, seq_len))
    bt = max(1, bh_tile)
    row_in = (3 * C * dk + C * dv) * dtype_bytes       # r, k, logw | v
    out_tile = bt * C * dv * dtype_bytes
    intra = C * C * dk * 4 + C * C * 4                 # exp(diff) + scores
    ws.add("in_windows", tiling.STREAM_SLOTS * bt * row_in)
    ws.add("out_tile", out_tile)
    ws.add("u", bt * dk * 4)
    ws.add("state_io", 2 * bt * dk * dv * 4)           # s0 in + s_out out
    ws.add("state_scratch", bt * dk * dv * 4)          # carried states
    ws.add("intra", intra)
    ws.add("straj_windows", tiling.STREAM_SLOTS * bt * dk * dv * 4,
           bwd_only=True)
    ws.add("dout_windows", tiling.STREAM_SLOTS * out_tile, bwd_only=True)
    ws.add("grad_tiles", bt * row_in, bwd_only=True)   # dr/dk/dv/dlogw
    ws.add("ds", 3 * bt * dk * dv * 4, bwd_only=True)  # scratch + ds0/dsf
    ws.add("du", bt * dk * 4, bwd_only=True)
    ws.add("intra_linearised", intra, bwd_only=True)
    return ws.total()


def choose_blocks(n_bh: int, seq_len: int, dk: int, dv: int, *,
                  target: int = 32, dtype_bytes: int = 4,
                  vmem_budget: int | None = None,
                  mode: str = "fwd") -> WkvBlocks | None:
    """Pick the (chunk, bh_tile), or None when not viable — the
    SeqBlocks-style decision function, via the shared
    ``core/tiling.joint_search`` in MobiRNN coarseness order: the BH tile
    seeds at ``n_bh`` (coarsest — one grid row), the chunk halves from
    ``target`` (clamped to T) first, and only when even C=1 does not fit
    does the BH tile halve — the same keep-the-batch-tile-coarse priority
    as lstm_seq.choose_batch_block.  This kernel always streams the time
    axis (there is no whole-T-resident layout), so the search runs with
    ``whole_t_first=False``: the coarsest chunk IS the coarsest residency.

    Returns None only when even (bh_tile=1, C=1) does not fit — i.e. the
    per-head state blocks themselves blow VMEM; T alone never disqualifies
    the plan (the grid streams chunks, residency is O(C) in sequence
    length).  Callers then route to the stepwise/XLA plan (fwd) or the
    oracle VJP (bwd)."""
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget

    def fits(bt: int, tc: int | None) -> bool:
        return working_set_bytes(seq_len, dk, dv, tc, dtype_bytes,
                                 mode=mode, bh_tile=bt) <= budget

    found = tiling.joint_search(
        n_bh, seq_len, fits, seed_batch_tile=n_bh, whole_t_first=False,
        chunk_start=max(1, min(target, seq_len)))
    if found is None:
        return None
    bt, c = found
    return WkvBlocks(c, bt)


def choose_chunk(seq_len: int, dk: int, dv: int, *, target: int = 32,
                 dtype_bytes: int = 4, vmem_budget: int | None = None,
                 mode: str = "fwd") -> WkvBlocks | None:
    """DEPRECATED thin alias for ``choose_blocks(1, ...)`` — the chunk-only
    decision at ``bh_tile=1`` (one BH row per grid step, grid steps exactly
    BH * ceil(T/C)).  ``choose_blocks`` is the joint surface every family
    exposes; call it directly."""
    import warnings
    warnings.warn("wkv6.choose_chunk is deprecated; call "
                  "choose_blocks(1, seq_len, dk, dv, ...)",
                  DeprecationWarning, stacklevel=2)
    return choose_blocks(1, seq_len, dk, dv, target=target,
                         dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
                         mode=mode)


# ---------------------------------------------------------------------------
# Shared chunk math — the single source of truth for fwd, traj, and bwd.
# ---------------------------------------------------------------------------
def _chunk_math(r, k, v, logw, u, s):
    """One chunk of the recurrence in f32.  r,k,logw: (C, dk); v: (C, dv);
    u: (dk,); s: (dk, dv).  Returns (out (C, dv), s_new (dk, dv)).

    Shared by the plain and trajectory-emitting kernel bodies (so the two
    forward dispatches are bit-identical) and DIFFERENTIATED via ``jax.vjp``
    inside the reverse-sweep kernel body — the chunk backward needs no
    hand-derived math, only the stored incoming state."""
    C = r.shape[0]
    L = jnp.cumsum(logw, axis=0)
    L_prev = L - logw
    # carry term r_i diag(exp(L_prev_i)) S  — one (C,dk)x(dk,dv) MXU matmul
    out = jax.lax.dot(r * jnp.exp(L_prev), s, preferred_element_type=F32)
    # intra-chunk: A[i,j,c] = exp(L_prev[i,c] - L[j,c]), j < i (exponent <= 0)
    diff = L_prev[:, None, :] - L[None, :, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (C, C), 1))
    # mask the EXPONENT, not the scores: the j >= i entries are positive and
    # overflow exp to inf under strong decay — the forward would mask the
    # infs away, but the einsum VJP then multiplies inf by the zeroed
    # cotangent and turns every gradient into NaN
    diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
    scores = jnp.einsum("ic,jc,ijc->ij", r, k, jnp.exp(diff),
                        preferred_element_type=F32)
    out = out + jax.lax.dot(scores, v, preferred_element_type=F32)
    # bonus diagonal term
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    out = out + bonus * v
    # state update
    L_last = L[-1]
    decay_j = jnp.exp(L_last[None, :] - L)
    s_new = (jnp.exp(L_last)[:, None] * s
             + jax.lax.dot((k * decay_j).T, v, preferred_element_type=F32))
    return out, s_new


# ---------------------------------------------------------------------------
# Kernel bodies — time windows stream through two-slot VMEM double buffers.
# ---------------------------------------------------------------------------
def _window_dma(hbm, buf, sems, j, slot, idx, *, ib, bt, chunk):
    """Async copy of chunk window ``idx`` of stream ``j`` into buffer slot
    ``slot``: a (bt, chunk, d) tile of the (BHp, Tp, d) HBM array (the
    wrapper zero-pads both axes, so the window is always in bounds).
    ``ib`` is the BH-tile id, captured ONCE at kernel top — calling
    ``pl.program_id`` inside a ``pl.when`` branch does not lower."""
    return pltpu.make_async_copy(
        hbm.at[pl.ds(ib * bt, bt), pl.ds(idx * chunk, chunk), :],
        buf.at[slot], sems.at[j, slot])


def _fwd_body(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, s0_ref, out_ref, s_out_ref,
              straj_ref, rbuf, kbuf, vbuf, lwbuf, state, sems):
    """Forward/trajectory body: chunk t's r/k/v/logw windows arrive through
    the two-slot pipeline (slot t%2 computes while slot (t+1)%2 prefetches),
    the bh_tile f32 states carry in VMEM scratch across the inner grid
    dimension, and the per-row chunk math is STATICALLY unrolled so results
    are bit-identical at any bh_tile."""
    ib = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    bt, chunk = rbuf.shape[1], rbuf.shape[2]
    streams = ((r_hbm, rbuf), (k_hbm, kbuf), (v_hbm, vbuf), (lw_hbm, lwbuf))

    def dma(j, slot, idx):
        hbm, buf = streams[j]
        return _window_dma(hbm, buf, sems, j, slot, idx, ib=ib, bt=bt,
                           chunk=chunk)

    @pl.when(t == 0)
    def _init():
        for j in range(len(streams)):                    # warm-up windows
            dma(j, 0, 0).start()
        state[...] = s0_ref[...].astype(F32)

    slot = jax.lax.rem(t, 2)

    @pl.when(t + 1 < nt)
    def _prefetch():
        nxt = jax.lax.rem(t + 1, 2)
        for j in range(len(streams)):
            dma(j, nxt, t + 1).start()

    for j in range(len(streams)):
        dma(j, slot, t).wait()

    r = rbuf[slot].astype(F32)
    k = kbuf[slot].astype(F32)
    v = vbuf[slot].astype(F32)
    logw = lwbuf[slot].astype(F32)
    for i in range(bt):                                  # static unroll
        s_in = state[i]
        if straj_ref is not None:
            straj_ref[i, 0] = s_in                # incoming state of chunk t
        out, s_new = _chunk_math(r[i], k[i], v[i], logw[i],
                                 u_ref[i].astype(F32), s_in)
        state[i] = s_new
        out_ref[i] = out.astype(out_ref.dtype)

    @pl.when(t == nt - 1)
    def _final():
        s_out_ref[...] = state[...].astype(s_out_ref.dtype)


def _kernel(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, s0_ref, out_ref, s_out_ref,
            rbuf, kbuf, vbuf, lwbuf, state, sems):
    _fwd_body(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, s0_ref, out_ref, s_out_ref,
              None, rbuf, kbuf, vbuf, lwbuf, state, sems)


def _traj_kernel(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, s0_ref, out_ref,
                 s_out_ref, straj_ref, rbuf, kbuf, vbuf, lwbuf, state, sems):
    """Trajectory-emitting forward: same math and dispatch count as
    ``_kernel``, plus the CHUNK-INCOMING states written to ``s_traj`` —
    the residual the reverse sweep re-linearises each chunk from."""
    _fwd_body(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, s0_ref, out_ref, s_out_ref,
              straj_ref, rbuf, kbuf, vbuf, lwbuf, state, sems)


def _bwd_kernel(r_hbm, k_hbm, v_hbm, lw_hbm, u_ref, straj_hbm, do_hbm,
                dsf_ref, dr_ref, dk_ref, dv_ref, dlw_ref, du_ref, ds0_ref,
                rbuf, kbuf, vbuf, lwbuf, dobuf, sbuf, ds_scr, du_scr, sems):
    """Reverse-time BPTT sweep over chunks — ONE dispatch for the whole
    backward.  Grid step t processes chunk nt-1-t; the r/k/v/logw/dout
    windows AND the stored chunk-incoming states stream through the same
    two-slot pipeline as the forward, in REVERSE chunk order (sweep window
    t+1 — chunk nt-2-t — prefetches while chunk nt-1-t computes).  The
    state cotangents ``ds`` carry across grid steps in VMEM scratch (seeded
    from the final-state cotangent at reverse step 0), ``du`` accumulates
    per row in scratch, and both are written once at the last reverse step,
    where ``ds0`` (the cotangent of the incoming state) is also emitted."""
    ib = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    bt, chunk = rbuf.shape[1], rbuf.shape[2]
    kc = nt - 1 - t                           # reverse-order chunk index
    win_streams = ((r_hbm, rbuf), (k_hbm, kbuf), (v_hbm, vbuf),
                   (lw_hbm, lwbuf), (do_hbm, dobuf))
    n_streams = len(win_streams) + 1          # + the s_traj state stream

    def dma(j, slot, idx):
        if j < len(win_streams):
            hbm, buf = win_streams[j]
            return _window_dma(hbm, buf, sems, j, slot, idx, ib=ib, bt=bt,
                               chunk=chunk)
        return pltpu.make_async_copy(         # (bt, 1, dk, dv) state window
            straj_hbm.at[pl.ds(ib * bt, bt), pl.ds(idx, 1), :, :],
            sbuf.at[slot], sems.at[j, slot])

    @pl.when(t == 0)
    def _init():
        for j in range(n_streams):            # warm-up: last chunk's windows
            dma(j, 0, kc).start()
        ds_scr[...] = dsf_ref[...].astype(F32)
        du_scr[...] = jnp.zeros_like(du_scr)

    slot = jax.lax.rem(t, 2)

    @pl.when(t + 1 < nt)
    def _prefetch():
        nxt = jax.lax.rem(t + 1, 2)
        for j in range(n_streams):
            dma(j, nxt, kc - 1).start()

    for j in range(n_streams):
        dma(j, slot, kc).wait()

    r = rbuf[slot].astype(F32)
    k = kbuf[slot].astype(F32)
    v = vbuf[slot].astype(F32)
    logw = lwbuf[slot].astype(F32)
    dout = dobuf[slot].astype(F32)
    for i in range(bt):                                  # static unroll
        _, chunk_vjp = jax.vjp(_chunk_math, r[i], k[i], v[i], logw[i],
                               u_ref[i].astype(F32), sbuf[slot, i, 0])
        dr, dkk, dvv, dlw, du, ds_in = chunk_vjp((dout[i], ds_scr[i]))
        ds_scr[i] = ds_in
        du_scr[i] = du_scr[i] + du
        dr_ref[i] = dr.astype(dr_ref.dtype)
        dk_ref[i] = dkk.astype(dk_ref.dtype)
        dv_ref[i] = dvv.astype(dv_ref.dtype)
        dlw_ref[i] = dlw.astype(dlw_ref.dtype)

    @pl.when(t == nt - 1)                     # reverse-last = chunk 0
    def _final():
        du_ref[...] = du_scr[...].astype(du_ref.dtype)
        ds0_ref[...] = ds_scr[...].astype(ds0_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (T % chunk == 0, BH % bh_tile == 0 — the entry pads)
# ---------------------------------------------------------------------------
_ANY = functools.partial(pl.BlockSpec, memory_space=pltpu.ANY)


def _fwd_call(r, k, v, logw, u, state, chunk, bh_tile, interpret,
              traj: bool):
    BH, T, dk = r.shape
    dv = v.shape[-1]
    assert T % chunk == 0 and BH % bh_tile == 0, (T, chunk, BH, bh_tile)
    nt = T // chunk
    bt = bh_tile
    in_specs = [_ANY(), _ANY(), _ANY(), _ANY()] + [
        pl.BlockSpec((bt, dk), lambda b, t: (b, 0)),
        pl.BlockSpec((bt, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((bt, chunk, dv), lambda b, t: (b, t, 0)),
        pl.BlockSpec((bt, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
        jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
    ]
    kernel = _kernel
    if traj:
        kernel = _traj_kernel
        out_specs.append(pl.BlockSpec((bt, 1, dk, dv),
                                      lambda b, t: (b, t, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((BH, nt, dk, dv), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(BH // bt, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((2, bt, chunk, dk), r.dtype),
                        pltpu.VMEM((2, bt, chunk, dk), k.dtype),
                        pltpu.VMEM((2, bt, chunk, dv), v.dtype),
                        pltpu.VMEM((2, bt, chunk, dk), logw.dtype),
                        pltpu.VMEM((bt, dk, dv), jnp.float32),
                        pltpu.SemaphoreType.DMA((4, 2))],
        interpret=interpret,
    )(r, k, v, logw, u, state)


def _bwd_call(r, k, v, logw, u, s_traj, dout, ds_fin, s0_dtype, chunk,
              bh_tile, interpret):
    BH, T, dk = r.shape
    dv = v.shape[-1]
    nt = T // chunk
    bt = bh_tile
    rev = nt - 1                              # reversed chunk index map

    in_specs = [_ANY(), _ANY(), _ANY(), _ANY()] + [
        pl.BlockSpec((bt, dk), lambda b, t: (b, 0)),
        _ANY(),                               # s_traj streams in reverse
        _ANY(),                               # dout streams in reverse
        pl.BlockSpec((bt, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((bt, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((bt, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((bt, chunk, dv), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((bt, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((bt, dk), lambda b, t: (b, 0)),
        pl.BlockSpec((bt, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(r.shape, r.dtype),
        jax.ShapeDtypeStruct(k.shape, k.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
        jax.ShapeDtypeStruct(logw.shape, logw.dtype),
        jax.ShapeDtypeStruct(u.shape, u.dtype),
        jax.ShapeDtypeStruct((BH, dk, dv), s0_dtype),
    ]
    return pl.pallas_call(
        _bwd_kernel,
        grid=(BH // bt, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((2, bt, chunk, dk), r.dtype),
                        pltpu.VMEM((2, bt, chunk, dk), k.dtype),
                        pltpu.VMEM((2, bt, chunk, dv), v.dtype),
                        pltpu.VMEM((2, bt, chunk, dk), logw.dtype),
                        pltpu.VMEM((2, bt, chunk, dv), dout.dtype),
                        pltpu.VMEM((2, bt, 1, dk, dv), jnp.float32),
                        pltpu.VMEM((bt, dk, dv), jnp.float32),
                        pltpu.VMEM((bt, dk), jnp.float32),
                        pltpu.SemaphoreType.DMA((6, 2))],
        interpret=interpret,
    )(r, k, v, logw, u, s_traj, dout, ds_fin)


# ---------------------------------------------------------------------------
# custom VJP — 1 dispatch fwd, 2 dispatches per value_and_grad
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _wkv6(r, k, v, logw, u, s0, chunk, bh_tile, bwd, interpret):
    out, s_out = _fwd_call(r, k, v, logw, u, s0, chunk, bh_tile, interpret,
                           traj=False)
    return out, s_out


def _wkv6_fwd(r, k, v, logw, u, s0, chunk, bh_tile, bwd, interpret):
    if bwd == ORACLE_BWD:
        out, s_out = _fwd_call(r, k, v, logw, u, s0, chunk, bh_tile,
                               interpret, traj=False)
        return (out, s_out), (r, k, v, logw, u, s0, None)
    out, s_out, s_traj = _fwd_call(r, k, v, logw, u, s0, chunk, bh_tile,
                                   interpret, traj=True)
    return (out, s_out), (r, k, v, logw, u, s0, s_traj)


def _oracle(r, k, v, logw, u, s0, chunk):
    """Batched pure-jnp reference with the kernel's exact output dtypes —
    the oracle-VJP fallback differentiates this."""
    from repro.kernels import ref

    out, s_out = jax.vmap(
        lambda rr, kk, vv, ww, uu, ss: ref.wkv6(rr, kk, vv, ww, uu, ss,
                                                chunk))(r, k, v, logw, u, s0)
    return out.astype(v.dtype), s_out.astype(jnp.float32)


def _wkv6_bwd(chunk, bh_tile, bwd, interpret, residuals, cots):
    r, k, v, logw, u, s0, s_traj = residuals
    dout, ds_fin = cots
    if bwd == ORACLE_BWD:
        _, oracle_vjp = jax.vjp(
            lambda *a: _oracle(*a, chunk), r, k, v, logw, u, s0)
        return oracle_vjp((dout, ds_fin))
    return _bwd_call(r, k, v, logw, u, s_traj, dout, ds_fin, s0.dtype,
                     chunk, bh_tile, interpret)


_wkv6.defvjp(_wkv6_fwd, _wkv6_bwd)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "bh_tile", "bwd", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: int = 32,
         bh_tile: int = 1, bwd: int = FUSED_BWD,
         interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 scan over full sequences — ONE Pallas dispatch.

    r, k, logw: (BH, T, dk); v: (BH, T, dv); u: (BH, dk);
    state: (BH, dk, dv).  Any T and BH — non-dividing axes are zero-padded
    to the next chunk/bh_tile multiple (identity on the state: logw = 0,
    zero kv; padded BH rows are fully zero and independent) and the padded
    output rows sliced off.  ``chunk`` is clamped to T and ``bh_tile`` to
    BH.  Returns (out (BH, T, dv), final state (BH, dk, dv) f32).

    Differentiable: under ``jax.grad`` the forward becomes the
    trajectory-emitting kernel and the backward ONE reverse-sweep dispatch
    (``bwd=FUSED_BWD``, the default) — or the oracle VJP replay
    (``bwd=ORACLE_BWD``) when the caller's ``choose_chunk(mode="bwd")``
    found no viable chunk.
    """
    BH, T, dk = r.shape
    chunk = max(1, min(chunk, T))
    bh_tile = max(1, min(bh_tile, BH))
    from repro.obs import trace as trace_lib
    tracer = trace_lib.get_tracer()
    if tracer.enabled:
        tracer.event("plan/dispatch", family="rwkv6", plan="chunked_scan",
                     chunk=chunk, bh_tile=bh_tile, bwd=bwd, n_bh=BH,
                     seq_len=T)
    pad = (-T) % chunk
    padb = (-BH) % bh_tile
    if pad or padb:
        def zpad(a):
            return jnp.pad(a, ((0, padb), (0, pad), (0, 0)))

        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
        if padb:
            u = jnp.pad(u, ((0, padb), (0, 0)))
            state = jnp.pad(state, ((0, padb), (0, 0), (0, 0)))
    out, s_out = _wkv6(r, k, v, logw, u, state, chunk, bh_tile, bwd,
                       interpret)
    if pad or padb:
        out = out[:BH, :T]
        s_out = s_out[:BH]
    return out, s_out
