"""RWKV6 chunked-scan Pallas TPU kernel — forward AND fused backward.

This is MobiRNN's coarse work-unit factorization applied to the RWKV6
recurrence: instead of T tiny sequential state updates (the "CUDA-style"
per-step plan, kernels/ref.wkv6_stepwise), the sequence is processed in
chunks of C steps.  Within a chunk everything is a dense MXU-friendly batch
of matmuls on VMEM tiles (one coarse work unit); only the (dk x dv) state
crosses chunk boundaries — it lives in a VMEM scratch accumulator across the
sequential chunk grid dimension, so it never round-trips to HBM during the
scan (the paper's preallocated-state-reuse rule).

Numerical safety: all within-chunk decay exponents are differences
L_a - L_b with a >= b of a running log-decay cumsum, hence <= 0 — no
exp overflow regardless of decay strength (logw <= 0).

Grid: (batch*heads, ceil(T/C)); the chunk dimension is innermost (sequential
on TPU), so the scratch state carries correctly.  Non-dividing T is
zero-padded at the END: padded steps have r = k = v = 0 and logw = 0, which
is the IDENTITY on the state (exp(0) = 1 decay, zero k^T v outer product)
and contributes zero output rows that the wrapper slices off — so padding
never changes results, only the grid extent.

Autodiff: ``pallas_call`` has no VJP rule, so ``wkv6`` wraps the kernel in a
``jax.custom_vjp`` mirroring kernels/lstm_seq.py.  Under differentiation the
forward runs a trajectory-emitting variant (same math, same single dispatch)
that additionally writes the CHUNK-INCOMING states ``s_traj
(BH, nt, dk, dv)`` — the residual the backward recomputes from — and the
backward runs the whole reverse-time sweep in ONE kernel dispatch: the grid
walks chunks in reverse via reversed index maps, the state cotangent ``ds``
lives in VMEM scratch across the sweep, ``du`` accumulates in scratch, and
each chunk's (dr, dk, dv, dlogw) falls out of ``jax.vjp`` of the pure chunk
math re-linearised from the stored incoming state.  ``value_and_grad`` is
exactly 2 Pallas dispatches at any T — O(1) in T, O(T/C) grid steps
(``analysis.count_pallas_grid_steps``).  ``bwd=ORACLE_BWD`` restores the
oracle-VJP fallback (differentiate kernels/ref.wkv6), used when
``choose_chunk(mode="bwd")`` finds no viable chunk.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization

F32 = jnp.float32

#: ``bwd=`` sentinel: differentiate the pure-jnp oracle instead of running
#: the fused reverse sweep (the principled fallback past the bwd budget).
ORACLE_BWD = 0
#: ``bwd=`` default: ONE reverse-order Pallas dispatch for the whole sweep.
FUSED_BWD = 1


# ---------------------------------------------------------------------------
# VMEM budget — the (chunk,) analogue of lstm_seq's (block_b, time_chunk).
# ---------------------------------------------------------------------------
class WkvBlocks(NamedTuple):
    """The chunked-scan kernel's tiling decision: the chunk length C.

    The work-unit-coarseness knob of the WKV6 plan — larger C means denser
    MXU matmuls and fewer grid steps (O(T/C)), at the price of the
    (C, C, dk) f32 intra-chunk decay tensor, the dominant VMEM term."""
    chunk: int


def working_set_bytes(seq_len: int, dk: int, dv: int, chunk: int,
                      dtype_bytes: int = 4, mode: str = "fwd") -> int:
    """VMEM working set of one (batch-head, chunk) grid step.

    ``mode="fwd"`` sizes the inference forward: the four (C, dk/dv) chunk
    tiles + the output tile, u, the s0/s_out blocks, the f32 state scratch,
    and the (C, C, dk) f32 intra-chunk decay tensor plus its (C, C) score
    matrix — the term that grows quadratically in C and makes the chunk
    length a real budget decision.

    ``mode="bwd"`` sizes the reverse-sweep dispatch, which strictly
    dominates the trajectory-emitting forward that feeds it: on top of the
    forward set it holds the stored chunk-incoming state tile, the dout
    cotangent tile, the mirrored (dr, dk, dv, dlogw) output tiles, the ds
    state-cotangent scratch + ds0/ds_fin blocks, the du accumulator, and a
    second copy of the intra-chunk tensors (the linearised chunk recompute
    keeps forward values live while the cotangent flows back) — roughly 3x
    the forward working set at typical head shapes.
    """
    if mode not in ("fwd", "bwd"):
        raise ValueError(f"mode must be 'fwd' or 'bwd', got {mode!r}")
    C = max(1, min(chunk, seq_len))
    tiles_in = (3 * C * dk + C * dv) * dtype_bytes     # r, k, logw, v
    out_tile = C * dv * dtype_bytes
    u_bytes = dk * 4
    state_io = 2 * dk * dv * 4                         # s0 in + s_out out
    scratch = dk * dv * 4                              # carried state
    intra = C * C * dk * 4 + C * C * 4                 # exp(diff) + scores
    total = tiles_in + out_tile + u_bytes + state_io + scratch + intra
    if mode == "bwd":
        total += dk * dv * 4                           # s_traj chunk tile
        total += out_tile                              # dout cotangent tile
        total += tiles_in                              # dr/dk/dv/dlogw tiles
        total += dk * dv * 4 + 2 * dk * dv * 4         # ds scratch + ds0/dsf
        total += dk * 4                                # du accumulator
        total += intra                                 # linearised recompute
    return total


def choose_chunk(seq_len: int, dk: int, dv: int, *, target: int = 32,
                 dtype_bytes: int = 4, vmem_budget: int | None = None,
                 mode: str = "fwd") -> WkvBlocks | None:
    """Pick the chunk length, or None when not viable — the SeqBlocks-style
    decision function the Fig 7 scheduler consumes via ``viable=``.

    Coarseness search in MobiRNN order: start from ``target`` (the config's
    chunk, clamped to T) and halve until the working set fits the budget.
    Returns None only when even C=1 does not fit — i.e. the per-head state
    blocks themselves blow VMEM; T alone never disqualifies the plan (the
    grid streams chunks, residency is O(C) in sequence length).  Callers
    then route to the stepwise/XLA plan (fwd) or the oracle VJP (bwd)."""
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget
    c = max(1, min(target, seq_len))
    while True:
        if working_set_bytes(seq_len, dk, dv, c, dtype_bytes,
                             mode=mode) <= budget:
            return WkvBlocks(c)
        if c == 1:
            return None
        c = max(c // 2, 1)


# ---------------------------------------------------------------------------
# Shared chunk math — the single source of truth for fwd, traj, and bwd.
# ---------------------------------------------------------------------------
def _chunk_math(r, k, v, logw, u, s):
    """One chunk of the recurrence in f32.  r,k,logw: (C, dk); v: (C, dv);
    u: (dk,); s: (dk, dv).  Returns (out (C, dv), s_new (dk, dv)).

    Shared by the plain and trajectory-emitting kernel bodies (so the two
    forward dispatches are bit-identical) and DIFFERENTIATED via ``jax.vjp``
    inside the reverse-sweep kernel body — the chunk backward needs no
    hand-derived math, only the stored incoming state."""
    C = r.shape[0]
    L = jnp.cumsum(logw, axis=0)
    L_prev = L - logw
    # carry term r_i diag(exp(L_prev_i)) S  — one (C,dk)x(dk,dv) MXU matmul
    out = jax.lax.dot(r * jnp.exp(L_prev), s, preferred_element_type=F32)
    # intra-chunk: A[i,j,c] = exp(L_prev[i,c] - L[j,c]), j < i (exponent <= 0)
    diff = L_prev[:, None, :] - L[None, :, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (C, C), 1))
    # mask the EXPONENT, not the scores: the j >= i entries are positive and
    # overflow exp to inf under strong decay — the forward would mask the
    # infs away, but the einsum VJP then multiplies inf by the zeroed
    # cotangent and turns every gradient into NaN
    diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
    scores = jnp.einsum("ic,jc,ijc->ij", r, k, jnp.exp(diff),
                        preferred_element_type=F32)
    out = out + jax.lax.dot(scores, v, preferred_element_type=F32)
    # bonus diagonal term
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    out = out + bonus * v
    # state update
    L_last = L[-1]
    decay_j = jnp.exp(L_last[None, :] - L)
    s_new = (jnp.exp(L_last)[:, None] * s
             + jax.lax.dot((k * decay_j).T, v, preferred_element_type=F32))
    return out, s_new


def _load_chunk(r_ref, k_ref, v_ref, lw_ref, u_ref):
    return (r_ref[0].astype(F32), k_ref[0].astype(F32),
            v_ref[0].astype(F32), lw_ref[0].astype(F32),
            u_ref[0].astype(F32))


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            out_ref, s_out_ref, state):
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    r, k, v, logw, u = _load_chunk(r_ref, k_ref, v_ref, lw_ref, u_ref)

    @pl.when(t == 0)
    def _init():
        state[...] = s0_ref[0].astype(F32)

    out, s_new = _chunk_math(r, k, v, logw, u, state[...])
    state[...] = s_new
    out_ref[0] = out.astype(out_ref.dtype)

    @pl.when(t == nt - 1)
    def _final():
        s_out_ref[0] = s_new.astype(s_out_ref.dtype)


def _traj_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                 out_ref, s_out_ref, straj_ref, state):
    """Trajectory-emitting forward: same math and dispatch count as
    ``_kernel``, plus the CHUNK-INCOMING state written to ``s_traj`` —
    the residual the reverse sweep re-linearises each chunk from."""
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    r, k, v, logw, u = _load_chunk(r_ref, k_ref, v_ref, lw_ref, u_ref)

    @pl.when(t == 0)
    def _init():
        state[...] = s0_ref[0].astype(F32)

    s = state[...]
    straj_ref[0, 0] = s                       # incoming state of chunk t
    out, s_new = _chunk_math(r, k, v, logw, u, s)
    state[...] = s_new
    out_ref[0] = out.astype(out_ref.dtype)

    @pl.when(t == nt - 1)
    def _final():
        s_out_ref[0] = s_new.astype(s_out_ref.dtype)


def _bwd_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, straj_ref, do_ref,
                dsf_ref, dr_ref, dk_ref, dv_ref, dlw_ref, du_ref, ds0_ref,
                ds_scr, du_scr):
    """Reverse-time BPTT sweep over chunks — ONE dispatch for the whole
    backward.  The grid's chunk dimension is index-mapped in REVERSE, so
    grid step t processes chunk nt-1-t; the state cotangent ``ds`` carries
    across grid steps in VMEM scratch (seeded from the final-state
    cotangent at reverse step 0), ``du`` accumulates in scratch and is
    written once at the last reverse step, where ``ds0`` (the cotangent of
    the incoming state) is also emitted."""
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    r, k, v, logw, u = _load_chunk(r_ref, k_ref, v_ref, lw_ref, u_ref)
    s_in = straj_ref[0, 0]                    # chunk-incoming state (f32)
    dout = do_ref[0].astype(F32)

    @pl.when(t == 0)
    def _init():
        ds_scr[...] = dsf_ref[0].astype(F32)
        du_scr[...] = jnp.zeros_like(du_scr)

    _, chunk_vjp = jax.vjp(_chunk_math, r, k, v, logw, u, s_in)
    dr, dk, dv, dlw, du, ds_in = chunk_vjp((dout, ds_scr[...]))
    ds_scr[...] = ds_in
    du_scr[...] = du_scr[...] + du[None, :]
    dr_ref[0] = dr.astype(dr_ref.dtype)
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)
    dlw_ref[0] = dlw.astype(dlw_ref.dtype)

    @pl.when(t == nt - 1)                     # reverse-last = chunk 0
    def _final():
        du_ref[0] = du_scr[0].astype(du_ref.dtype)
        ds0_ref[0] = ds_in.astype(ds0_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (T % chunk == 0 — the public entry pads)
# ---------------------------------------------------------------------------
def _chunk_specs(chunk: int, dk: int, dv: int):
    return [
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
        pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
        pl.BlockSpec((1, dk), lambda b, t: (b, 0)),
    ]


def _fwd_call(r, k, v, logw, u, state, chunk, interpret, traj: bool):
    BH, T, dk = r.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nt = T // chunk
    in_specs = _chunk_specs(chunk, dk, dv) + [
        pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
        pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
        jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
    ]
    kernel = _kernel
    if traj:
        kernel = _traj_kernel
        out_specs.append(pl.BlockSpec((1, 1, dk, dv),
                                      lambda b, t: (b, t, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((BH, nt, dk, dv), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(BH, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)


def _bwd_call(r, k, v, logw, u, s_traj, dout, ds_fin, s0_dtype, chunk,
              interpret):
    BH, T, dk = r.shape
    dv = v.shape[-1]
    nt = T // chunk
    rev = nt - 1                              # reversed chunk index map

    in_specs = [
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dv), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, dk), lambda b, t: (b, 0)),
        pl.BlockSpec((1, 1, dk, dv), lambda b, t: (b, rev - t, 0, 0)),
        pl.BlockSpec((1, chunk, dv), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dv), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, chunk, dk), lambda b, t: (b, rev - t, 0)),
        pl.BlockSpec((1, dk), lambda b, t: (b, 0)),
        pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(r.shape, r.dtype),
        jax.ShapeDtypeStruct(k.shape, k.dtype),
        jax.ShapeDtypeStruct(v.shape, v.dtype),
        jax.ShapeDtypeStruct(logw.shape, logw.dtype),
        jax.ShapeDtypeStruct(u.shape, u.dtype),
        jax.ShapeDtypeStruct((BH, dk, dv), s0_dtype),
    ]
    return pl.pallas_call(
        _bwd_kernel,
        grid=(BH, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32),
                        pltpu.VMEM((1, dk), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s_traj, dout, ds_fin)


# ---------------------------------------------------------------------------
# custom VJP — 1 dispatch fwd, 2 dispatches per value_and_grad
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _wkv6(r, k, v, logw, u, s0, chunk, bwd, interpret):
    out, s_out = _fwd_call(r, k, v, logw, u, s0, chunk, interpret,
                           traj=False)
    return out, s_out


def _wkv6_fwd(r, k, v, logw, u, s0, chunk, bwd, interpret):
    if bwd == ORACLE_BWD:
        out, s_out = _fwd_call(r, k, v, logw, u, s0, chunk, interpret,
                               traj=False)
        return (out, s_out), (r, k, v, logw, u, s0, None)
    out, s_out, s_traj = _fwd_call(r, k, v, logw, u, s0, chunk, interpret,
                                   traj=True)
    return (out, s_out), (r, k, v, logw, u, s0, s_traj)


def _oracle(r, k, v, logw, u, s0, chunk):
    """Batched pure-jnp reference with the kernel's exact output dtypes —
    the oracle-VJP fallback differentiates this."""
    from repro.kernels import ref

    out, s_out = jax.vmap(
        lambda rr, kk, vv, ww, uu, ss: ref.wkv6(rr, kk, vv, ww, uu, ss,
                                                chunk))(r, k, v, logw, u, s0)
    return out.astype(v.dtype), s_out.astype(jnp.float32)


def _wkv6_bwd(chunk, bwd, interpret, residuals, cots):
    r, k, v, logw, u, s0, s_traj = residuals
    dout, ds_fin = cots
    if bwd == ORACLE_BWD:
        _, oracle_vjp = jax.vjp(
            lambda *a: _oracle(*a, chunk), r, k, v, logw, u, s0)
        return oracle_vjp((dout, ds_fin))
    return _bwd_call(r, k, v, logw, u, s_traj, dout, ds_fin, s0.dtype,
                     chunk, interpret)


_wkv6.defvjp(_wkv6_fwd, _wkv6_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "bwd", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: int = 32,
         bwd: int = FUSED_BWD,
         interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 scan over full sequences — ONE Pallas dispatch.

    r, k, logw: (BH, T, dk); v: (BH, T, dv); u: (BH, dk);
    state: (BH, dk, dv).  Any T — non-dividing sequences are zero-padded to
    the next chunk multiple (identity on the state: logw = 0, zero kv) and
    the padded output rows sliced off.  ``chunk`` is clamped to T.
    Returns (out (BH, T, dv), final state (BH, dk, dv) f32).

    Differentiable: under ``jax.grad`` the forward becomes the
    trajectory-emitting kernel and the backward ONE reverse-sweep dispatch
    (``bwd=FUSED_BWD``, the default) — or the oracle VJP replay
    (``bwd=ORACLE_BWD``) when the caller's ``choose_chunk(mode="bwd")``
    found no viable chunk.
    """
    BH, T, dk = r.shape
    chunk = max(1, min(chunk, T))
    from repro.obs import trace as trace_lib
    tracer = trace_lib.get_tracer()
    if tracer.enabled:
        tracer.event("plan/dispatch", family="rwkv6", plan="chunked_scan",
                     chunk=chunk, bwd=bwd, n_bh=BH, seq_len=T)
    pad = (-T) % chunk
    if pad:
        def zpad(a):
            return jnp.pad(a, ((0, 0), (0, pad), (0, 0)))

        r, k, v, logw = zpad(r), zpad(k), zpad(v), zpad(logw)
    out, s_out = _wkv6(r, k, v, logw, u, state, chunk, bwd, interpret)
    return (out[:, :T] if pad else out), s_out
