"""RWKV6 chunked-scan Pallas TPU kernel.

This is MobiRNN's coarse work-unit factorization applied to the RWKV6
recurrence: instead of T tiny sequential state updates (the "CUDA-style"
per-step plan, kernels/ref.wkv6_stepwise), the sequence is processed in
chunks of C steps.  Within a chunk everything is a dense MXU-friendly batch
of matmuls on VMEM tiles (one coarse work unit); only the (dk x dv) state
crosses chunk boundaries — it lives in a VMEM scratch accumulator across the
sequential chunk grid dimension, so it never round-trips to HBM during the
scan (the paper's preallocated-state-reuse rule).

Numerical safety: all within-chunk decay exponents are differences
L_a - L_b with a >= b of a running log-decay cumsum, hence <= 0 — no
exp overflow regardless of decay strength (logw <= 0).

Grid: (batch*heads, T/C); the chunk dimension is innermost (sequential on
TPU), so the scratch state carries correctly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
            out_ref, s_out_ref, state):
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    f32 = jnp.float32
    r = r_ref[0].astype(f32)        # (C, dk)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)        # (C, dv)
    logw = lw_ref[0].astype(f32)    # (C, dk)
    u = u_ref[0].astype(f32)        # (dk,)
    C = r.shape[0]

    @pl.when(t == 0)
    def _init():
        state[...] = s0_ref[0].astype(f32)

    s = state[...]                  # (dk, dv)
    L = jnp.cumsum(logw, axis=0)
    L_prev = L - logw
    # carry term r_i diag(exp(L_prev_i)) S  — one (C,dk)x(dk,dv) MXU matmul
    out = jax.lax.dot(r * jnp.exp(L_prev), s,
                      preferred_element_type=f32)
    # intra-chunk: A[i,j,c] = exp(L_prev[i,c] - L[j,c]), j < i (exponent <= 0)
    diff = L_prev[:, None, :] - L[None, :, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (C, C), 1))
    scores = jnp.einsum("ic,jc,ijc->ij", r, k, jnp.exp(diff),
                        preferred_element_type=f32)
    scores = jnp.where(mask, scores, 0.0)
    out = out + jax.lax.dot(scores, v, preferred_element_type=f32)
    # bonus diagonal term
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    out = out + bonus * v
    # state update
    L_last = L[-1]
    decay_j = jnp.exp(L_last[None, :] - L)
    s_new = (jnp.exp(L_last)[:, None] * s
             + jax.lax.dot((k * decay_j).T, v, preferred_element_type=f32))
    state[...] = s_new
    out_ref[0] = out.astype(out_ref.dtype)

    @pl.when(t == nt - 1)
    def _final():
        s_out_ref[0] = s_new.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, state: jax.Array, *, chunk: int = 32,
         interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Chunked RWKV6 scan over full sequences.

    r, k, logw: (BH, T, dk); v: (BH, T, dv); u: (BH, dk);
    state: (BH, dk, dv).  T % chunk == 0.
    Returns (out (BH, T, dv), final state (BH, dk, dv)).
    """
    BH, T, dk = r.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nt = T // chunk
    out, s_out = pl.pallas_call(
        _kernel,
        grid=(BH, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, dk), lambda b, t: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), v.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, state)
    return out, s_out
