"""Public jit'd entry points for the Pallas kernels.

Block sizes are chosen by core/factorization.choose_block (the MobiRNN
coarse-factorization rule) unless explicitly overridden.  On this CPU-only
container `interpret=True` executes the kernel bodies in Python for
correctness validation; on TPU pass `interpret=False`.
"""
from __future__ import annotations

import jax

from repro.core import factorization
from repro.kernels import decode_attn as _decode_attn
from repro.kernels import lstm_cell as _lstm_cell
from repro.kernels import wkv6 as _wkv6


def lstm_cell(w: jax.Array, b: jax.Array, x: jax.Array, c: jax.Array,
              h: jax.Array, *, interpret: bool = True,
              block_b: int | None = None, block_h: int | None = None
              ) -> tuple[jax.Array, jax.Array]:
    B, H = c.shape
    K = w.shape[0]
    if block_b is None or block_h is None:
        bm, bn, _ = factorization.choose_block(B, 4 * H, K)
        block_b = block_b or bm
        block_h = block_h or max(bn // 4, 1)
    return _lstm_cell.lstm_cell(w, b, x, c, h, block_b=block_b,
                                block_h=block_h, interpret=interpret)


def lstm_seq(w: jax.Array, b: jax.Array, x: jax.Array, *,
             interpret: bool = True, block_b: int | None = None,
             time_chunk: int | None = None,
             bwd_block_b: int | None = None,
             bwd_time_chunk: int | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Whole-sequence stacked LSTM — ONE kernel dispatch for all T steps
    (and, under ``jax.grad``, ONE reverse-sweep dispatch for the backward).

    w: (L, P+H, 4H) stacked weights (lstm_seq.stack_params); b: (L, 4H);
    x: (B, T, P) padded input.  Returns final (c, h), each (L, B, H).
    ``block_b``/``time_chunk`` tile the forward (None = auto via
    ``choose_batch_block``: whole-T VMEM residency when it fits, otherwise
    double-buffered time streaming); ``bwd_block_b``/``bwd_time_chunk``
    tile the training path (``bwd_block_b=0`` forces the oracle-VJP
    fallback).  Raises ValueError when the weight stack exceeds the VMEM
    budget even at (bm=1, tc=1) — callers route to the per-cell
    ``lstm_cell`` fallback (see core/lstm.forward_fused_seq, which
    automates both the stacking and the fallback).
    """
    from repro.kernels import lstm_seq as _lstm_seq
    return _lstm_seq.lstm_seq(w, b, x, block_b=block_b,
                              time_chunk=time_chunk,
                              bwd_block_b=bwd_block_b,
                              bwd_time_chunk=bwd_time_chunk,
                              interpret=interpret)


def lstm_seq_q8(w: jax.Array, b: jax.Array, x: jax.Array, *,
                interpret: bool = True, block_b: int | None = None,
                time_chunk: int | None = None,
                bwd_block_b: int | None = None,
                bwd_time_chunk: int | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Int8-weight whole-sequence stacked LSTM — same single-dispatch
    contract as ``lstm_seq`` but ``w`` is quantized to per-output-channel
    symmetric int8 inside (kernels/ref.quantize_q8) and the kernels hold
    the stack in VMEM as int8 + f32 scales, quartering the dominant weight
    term.  Oracle: kernels/ref.lstm_seq_q8; training runs the q8 reverse
    sweep with straight-through master-weight gradients (still exactly 2
    dispatches per ``value_and_grad``).
    """
    from repro.kernels import lstm_seq as _lstm_seq
    return _lstm_seq.lstm_seq_q8(w, b, x, block_b=block_b,
                                 time_chunk=time_chunk,
                                 bwd_block_b=bwd_block_b,
                                 bwd_time_chunk=bwd_time_chunk,
                                 interpret=interpret)


def wkv6(r, k, v, logw, u, state, *, chunk: int = 32,
         bwd: int = _wkv6.FUSED_BWD, interpret: bool = True):
    return _wkv6.wkv6(r, k, v, logw, u, state, chunk=chunk, bwd=bwd,
                      interpret=interpret)


def decode_attn(q, k_cache, v_cache, lengths, *, scale=None,
                block_s: int = 128, interpret: bool = True):
    return _decode_attn.decode_attn(q, k_cache, v_cache, lengths,
                                    scale=scale, block_s=block_s,
                                    interpret=interpret)


def flash_prefill(q, k, v, *, window: int = 0, scale=None,
                  q_block: int = 128, k_block: int = 128,
                  interpret: bool = True):
    from repro.kernels import flash_prefill as _fp
    return _fp.flash_prefill(q, k, v, window=window, scale=scale,
                             q_block=q_block, k_block=k_block,
                             interpret=interpret)
