"""Sequence-resident fused stacked-LSTM Pallas kernel.

MobiRNN's §3.2-3.3 lesson is that RNN latency on a constrained accelerator
is won by coarsening work units and keeping state resident.  The per-cell
kernel (kernels/lstm_cell.py) coarsens WITHIN a timestep but still launches
one ``pallas_call`` per cell per step — T x L dispatches, with the gate
weights re-read from HBM every time.  This kernel moves the ENTIRE time loop
inside one ``pallas_call``:

* grid over batch tiles — batch rows are independent, so they tile freely;
* ``jax.lax.fori_loop`` over T inside the kernel body;
* stacked per-layer weights ``(L, P+H, 4H)`` loaded into VMEM once and
  reused across all T timesteps (P = max(input_dim, H), rows zero-padded so
  every layer shares one shape — same trick as wavefront.stack_homogeneous);
* ``(c, h)`` carried in VMEM scratch, so recurrent state never round-trips
  HBM between steps — the paper's preallocation bound realised at kernel
  level.

Dispatch count is O(1) in sequence length instead of O(T*L)
(``analysis.count_kernel_dispatches`` asserts this in tests and benchmarks).

Why the grid does NOT tile the hidden dimension: h_t feeds the gates of
step t+1 across ALL hidden columns, so a hidden tile would need the other
tiles' h before its own time loop could advance — the recurrence makes
hidden tiles non-independent.  When the ``(L, P+H, 4H)`` weight stack (plus
state and the input block) exceeds the VMEM budget, ``choose_batch_block``
returns None and callers fall back to the per-cell kernel, which DOES tile
hidden because it re-synchronises through HBM every step.  See
core/lstm.py for the four-plan decision table.

Autodiff: ``pallas_call`` has no VJP rule, so ``lstm_seq`` wraps the kernel
in a ``jax.custom_vjp`` whose backward pass differentiates the pure-jnp
oracle (kernels/ref.lstm_seq) — numerically identical forward math, so the
gradients are exact (tests/test_lstm_seq.py checks against end-to-end
reference grads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Parameter stacking — one (L, P+H, 4H) weight block the kernel loads once.
# ---------------------------------------------------------------------------
def stack_params(layers: list[dict], hidden: int
                 ) -> tuple[jax.Array, jax.Array, int]:
    """Stack per-layer cell params to (L, P+H, 4H) / (L, 4H).

    ``layers`` are PLAIN (un-annotated) per-layer dicts with "w" of shape
    (in_dim_i + H, 4H).  Rows are rearranged to [input rows | h rows] with
    the input rows zero-padded to P = max(max_i in_dim_i, H), so one VMEM
    block serves every layer; callers zero-pad the raw input to width P
    (pad_input).  Padding rows multiply padded zeros — exactly equivalent.
    Returns (w_stack, b_stack, P).
    """
    in_dims = [layer["w"].shape[0] - hidden for layer in layers]
    p_width = max(max(in_dims), hidden)
    ws, bs = [], []
    for layer, in_dim in zip(layers, in_dims):
        w = layer["w"]
        if in_dim < p_width:
            pad = jnp.zeros((p_width - in_dim, 4 * hidden), w.dtype)
            w = jnp.concatenate([w[:in_dim], pad, w[in_dim:]], axis=0)
        ws.append(w)
        bs.append(layer["b"])
    return jnp.stack(ws), jnp.stack(bs), p_width


def pad_input(x: jax.Array, p_width: int) -> jax.Array:
    """Zero-pad x: (B, T, D) to (B, T, P) to match the stacked weight rows."""
    d = x.shape[-1]
    if d == p_width:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, p_width - d)))


# ---------------------------------------------------------------------------
# VMEM budget — the MobiRNN packing rule applied to the whole sequence.
# ---------------------------------------------------------------------------
def working_set_bytes(seq_len: int, n_layers: int, p_width: int, hidden: int,
                      block_b: int, dtype_bytes: int = 4,
                      w_dtype_bytes: int | None = None) -> int:
    """Kernel working set for one grid step: stacked weights + the batch
    tile's whole input sequence + f32 (c,h) scratch + output blocks.

    ``dtype_bytes`` sizes activations/outputs; ``w_dtype_bytes`` sizes the
    weight stack (defaults to ``dtype_bytes`` — pass it explicitly under
    mixed precision, e.g. bf16 activations over f32 parameters)."""
    wb = dtype_bytes if w_dtype_bytes is None else w_dtype_bytes
    weights = n_layers * (p_width + hidden) * 4 * hidden * wb
    biases = n_layers * 4 * hidden * wb
    x_block = block_b * seq_len * p_width * dtype_bytes
    state = 2 * n_layers * block_b * hidden * 4          # f32 scratch
    outs = 2 * n_layers * block_b * hidden * dtype_bytes
    return weights + biases + x_block + state + outs


def choose_batch_block(batch: int, seq_len: int, n_layers: int,
                       p_width: int, hidden: int, dtype_bytes: int = 4,
                       vmem_budget: int | None = None,
                       w_dtype_bytes: int | None = None) -> int | None:
    """Pick the batch tile, or None when the kernel is not viable.

    Seeds the tile from factorization.choose_block on the per-step gate
    matmul (B, P+H) x (P+H, 4H) — the coarsest MXU-aligned block — then
    halves it until the sequence-resident working set fits the budget.
    Returns None when even a bm=1 tile cannot fit — either the weight
    stack itself blows VMEM (large H/L) or the whole-sequence input block
    does (very large T: the kernel keeps all T timesteps resident;
    time-tiling the input DMA is a ROADMAP open item).  Callers then fall
    back to the per-cell kernel.
    """
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget
    bm, _, _ = factorization.choose_block(
        batch, 4 * hidden, p_width + hidden, bytes_per_elem=dtype_bytes,
        vmem_budget=budget)
    bm = min(bm, batch)
    while bm >= 1:
        if working_set_bytes(seq_len, n_layers, p_width, hidden, bm,
                             dtype_bytes, w_dtype_bytes) <= budget:
            return bm
        if bm == 1:
            break
        bm = max(bm // 2, 1)
    return None


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------
def _seq_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, c_scr, h_scr,
                *, n_layers: int, seq_len: int, p_width: int):
    """One batch tile runs the whole (T x L) recurrence from VMEM.

    x_ref: (T, bm, P) time-major input tile; w_ref: (L, P+H, 4H);
    b_ref: (L, 4H); c_scr/h_scr: (L, bm, H) f32 VMEM scratch that IS the
    paper's preallocated state — written every step, never leaving VMEM.
    """
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, carry):
        inp = x_ref[pl.ds(t, 1)][0].astype(F32)          # (bm, P)
        for layer in range(n_layers):                    # static unroll
            w = w_ref[layer]                             # (P+H, 4H)
            # one coarse MXU work unit per layer: all four gates at once,
            # split as x-part + h-part to skip an in-loop concatenate
            gates = (
                jax.lax.dot_general(inp, w[:p_width],
                                    (((1,), (0,)), ((), ())),
                                    preferred_element_type=F32)
                + jax.lax.dot_general(h_scr[layer], w[p_width:],
                                      (((1,), (0,)), ((), ())),
                                      preferred_element_type=F32)
                + b_ref[layer].astype(F32))
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            c_new = (jax.nn.sigmoid(f) * c_scr[layer]
                     + jax.nn.sigmoid(i) * jnp.tanh(g))
            h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
            c_scr[layer] = c_new
            h_scr[layer] = h_new
            hidden = h_new.shape[-1]
            inp = h_new if p_width == hidden else \
                jnp.pad(h_new, ((0, 0), (0, p_width - hidden)))
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _lstm_seq_call(w: jax.Array, b: jax.Array, x: jax.Array,
                   block_b: int, interpret: bool
                   ) -> tuple[jax.Array, jax.Array]:
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    out = jax.ShapeDtypeStruct((L, B, H), x.dtype)
    kernel = functools.partial(_seq_kernel, n_layers=L, seq_len=T,
                               p_width=P)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(B, bm),),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_shape=[out, out],
        scratch_shapes=[
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
        ],
        interpret=interpret,
    )(xt, w, b)


# ---------------------------------------------------------------------------
# Differentiable entry point
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _lstm_seq(w, b, x, block_b, interpret):
    return _lstm_seq_call(w, b, x, block_b, interpret)


def _lstm_seq_fwd(w, b, x, block_b, interpret):
    return _lstm_seq_call(w, b, x, block_b, interpret), (w, b, x)


def _lstm_seq_bwd(block_b, interpret, residuals, cotangents):
    from repro.kernels import ref
    w, b, x = residuals
    _, vjp = jax.vjp(ref.lstm_seq, w, b, x)
    return vjp(cotangents)


_lstm_seq.defvjp(_lstm_seq_fwd, _lstm_seq_bwd)


def lstm_seq(w: jax.Array, b: jax.Array, x: jax.Array, *,
             block_b: int | None = None, interpret: bool = True
             ) -> tuple[jax.Array, jax.Array]:
    """Whole-sequence stacked LSTM in ONE kernel dispatch.

    w: (L, P+H, 4H) stacked gate weights (stack_params); b: (L, 4H);
    x: (B, T, P) input zero-padded to width P (pad_input).
    Returns final (c, h), each (L, B, H).  Oracle: kernels/ref.lstm_seq.
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, xw = x.shape
    assert w.shape[1] == P + H and xw == P, (w.shape, x.shape)
    if block_b is None:
        block_b = choose_batch_block(
            B, T, L, P, H, dtype_bytes=jnp.dtype(x.dtype).itemsize,
            w_dtype_bytes=jnp.dtype(w.dtype).itemsize)
        if block_b is None:
            raise ValueError(
                f"sequence-resident working set (L={L}, P+H={P + H}, "
                f"4H={4 * H}, T={T}) exceeds the VMEM budget even at "
                "batch tile 1; use the per-cell fallback "
                "(core/lstm.forward_fused_seq routes this automatically)")
    return _lstm_seq(w, b, x, block_b, interpret)
