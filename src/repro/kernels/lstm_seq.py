"""Sequence-resident fused stacked-LSTM Pallas kernel.

MobiRNN's §3.2-3.3 lesson is that RNN latency on a constrained accelerator
is won by coarsening work units and keeping state resident.  The per-cell
kernel (kernels/lstm_cell.py) coarsens WITHIN a timestep but still launches
one ``pallas_call`` per cell per step — T x L dispatches, with the gate
weights re-read from HBM every time.  This kernel moves the ENTIRE time loop
inside one ``pallas_call``:

* grid over batch tiles — batch rows are independent, so they tile freely;
* ``jax.lax.fori_loop`` over T inside the kernel body;
* stacked per-layer weights ``(L, P+H, 4H)`` loaded into VMEM once and
  reused across all T timesteps (P = max(input_dim, H), rows zero-padded so
  every layer shares one shape — same trick as wavefront.stack_homogeneous);
* ``(c, h)`` carried in VMEM scratch, so recurrent state never round-trips
  HBM between steps — the paper's preallocation bound realised at kernel
  level.

Dispatch count is O(1) in sequence length instead of O(T*L)
(``analysis.count_kernel_dispatches`` asserts this in tests and benchmarks).

Why the grid does NOT tile the hidden dimension: h_t feeds the gates of
step t+1 across ALL hidden columns, so a hidden tile would need the other
tiles' h before its own time loop could advance — the recurrence makes
hidden tiles non-independent.  When the ``(L, P+H, 4H)`` weight stack (plus
state and the input block) exceeds the VMEM budget, ``choose_batch_block``
returns None and callers fall back to the per-cell kernel, which DOES tile
hidden because it re-synchronises through HBM every step.  See
core/lstm.py for the four-plan decision table.

Autodiff: ``pallas_call`` has no VJP rule, so ``lstm_seq`` wraps the kernel
in a ``jax.custom_vjp``.  Under differentiation the forward runs a
trajectory-emitting variant of the kernel (same math, same single dispatch)
that additionally writes the per-step ``(c, h)`` trajectory — two
``(T, L, B, H)`` f32 residuals — and the backward runs the whole
reverse-time BPTT sweep in ONE kernel dispatch (kernels/lstm_seq_bwd.py):
gates are recomputed from the stored trajectory, ``dw``/``db`` accumulate in
f32 VMEM scratch across batch tiles, and the ``(dc, dh)`` carries never
leave VMEM.  When ``choose_batch_block(mode="bwd")`` finds no batch tile
whose backward working set (~3x the forward one: trajectories + dw scratch
+ dx block ride along) fits the budget, the backward falls back to
differentiating the pure-jnp oracle (kernels/ref.lstm_seq) — numerically
identical forward math, so gradients stay exact either way
(tests/test_lstm_seq.py checks both paths against end-to-end reference
grads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Parameter stacking — one (L, P+H, 4H) weight block the kernel loads once.
# ---------------------------------------------------------------------------
def stack_params(layers: list[dict], hidden: int
                 ) -> tuple[jax.Array, jax.Array, int]:
    """Stack per-layer cell params to (L, P+H, 4H) / (L, 4H).

    ``layers`` are PLAIN (un-annotated) per-layer dicts with "w" of shape
    (in_dim_i + H, 4H).  Rows are rearranged to [input rows | h rows] with
    the input rows zero-padded to P = max(max_i in_dim_i, H), so one VMEM
    block serves every layer; callers zero-pad the raw input to width P
    (pad_input).  Padding rows multiply padded zeros — exactly equivalent.
    Returns (w_stack, b_stack, P).
    """
    in_dims = [layer["w"].shape[0] - hidden for layer in layers]
    p_width = max(max(in_dims), hidden)
    ws, bs = [], []
    for layer, in_dim in zip(layers, in_dims):
        w = layer["w"]
        if in_dim < p_width:
            pad = jnp.zeros((p_width - in_dim, 4 * hidden), w.dtype)
            w = jnp.concatenate([w[:in_dim], pad, w[in_dim:]], axis=0)
        ws.append(w)
        bs.append(layer["b"])
    return jnp.stack(ws), jnp.stack(bs), p_width


def pad_input(x: jax.Array, p_width: int) -> jax.Array:
    """Zero-pad x: (B, T, D) to (B, T, P) to match the stacked weight rows."""
    d = x.shape[-1]
    if d == p_width:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, p_width - d)))


# ---------------------------------------------------------------------------
# VMEM budget — the MobiRNN packing rule applied to the whole sequence.
# ---------------------------------------------------------------------------
def working_set_bytes(seq_len: int, n_layers: int, p_width: int, hidden: int,
                      block_b: int, dtype_bytes: int = 4,
                      w_dtype_bytes: int | None = None,
                      mode: str = "fwd") -> int:
    """Kernel working set for one grid step, per phase.

    ``mode="fwd"`` sizes the inference forward: stacked weights + the batch
    tile's whole input sequence + f32 (c,h) scratch + output blocks.

    ``mode="bwd"`` sizes the TRAINING working set — the reverse-sweep kernel
    (kernels/lstm_seq_bwd.py), which strictly dominates the
    trajectory-emitting forward that feeds it, so one number gates both
    dispatches.  On top of the forward set it holds the two (T, L, bm, H)
    f32 trajectory residuals, the f32 dw/db accumulator scratch (a second
    weight-stack-sized block), the dw/db output blocks, the dx output block
    (mirroring the input block) and the (dc, dh) carry scratch — roughly 3x
    the forward working set at the paper's shapes.

    ``dtype_bytes`` sizes activations/outputs; ``w_dtype_bytes`` sizes the
    weight stack (defaults to ``dtype_bytes`` — pass it explicitly under
    mixed precision, e.g. bf16 activations over f32 parameters)."""
    if mode not in ("fwd", "bwd"):
        raise ValueError(f"mode must be 'fwd' or 'bwd', got {mode!r}")
    wb = dtype_bytes if w_dtype_bytes is None else w_dtype_bytes
    weights = n_layers * (p_width + hidden) * 4 * hidden * wb
    biases = n_layers * 4 * hidden * wb
    x_block = block_b * seq_len * p_width * dtype_bytes
    state = 2 * n_layers * block_b * hidden * 4          # f32 scratch
    outs = 2 * n_layers * block_b * hidden * dtype_bytes
    total = weights + biases + x_block + state + outs
    if mode == "bwd":
        traj = 2 * seq_len * n_layers * block_b * hidden * 4   # f32 residual
        dw_scratch = weights // wb * 4 + biases // wb * 4      # f32 accum
        dw_out = weights + biases                              # param dtype
        dx_block = x_block                                     # dx mirrors x
        # (dc, dh) carries reuse `state`; the final-state cotangent blocks:
        cots = 2 * n_layers * block_b * hidden * dtype_bytes
        total += traj + dw_scratch + dw_out + dx_block + cots
    return total


def choose_batch_block(batch: int, seq_len: int, n_layers: int,
                       p_width: int, hidden: int, dtype_bytes: int = 4,
                       vmem_budget: int | None = None,
                       w_dtype_bytes: int | None = None,
                       mode: str = "fwd") -> int | None:
    """Pick the batch tile, or None when the kernel is not viable.

    Seeds the tile from factorization.choose_block on the per-step gate
    matmul (B, P+H) x (P+H, 4H) — the coarsest MXU-aligned block — then
    halves it until the sequence-resident working set fits the budget.
    ``mode="bwd"`` sizes the TRAINING working set instead (trajectory
    residuals + gradient accumulators, see ``working_set_bytes``) — under
    ``jax.grad`` this is the number that matters, and it is ~3x the forward
    one, so a batch tile that is fine for inference can be non-viable for
    training.  Returns None when even a bm=1 tile cannot fit — either the
    weight stack itself blows VMEM (large H/L) or the whole-sequence input
    block does (very large T: the kernel keeps all T timesteps resident;
    time-tiling the input DMA is a ROADMAP open item).  Callers then fall
    back to the per-cell kernel (fwd) or the oracle VJP (bwd).
    """
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget
    bm, _, _ = factorization.choose_block(
        batch, 4 * hidden, p_width + hidden, bytes_per_elem=dtype_bytes,
        vmem_budget=budget)
    bm = min(bm, batch)
    while bm >= 1:
        if working_set_bytes(seq_len, n_layers, p_width, hidden, bm,
                             dtype_bytes, w_dtype_bytes, mode=mode) <= budget:
            return bm
        if bm == 1:
            break
        bm = max(bm // 2, 1)
    return None


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------
def _step_layers(inp, w_ref, b_ref, c_scr, h_scr, *, n_layers: int,
                 p_width: int) -> None:
    """Advance all L layers one timestep, updating (c, h) scratch in place.

    ``inp``: (bm, P) f32 — this step's (padded) input.  Shared by the plain,
    trajectory-emitting, and backward-recompute kernel bodies so the three
    dispatches stay bit-identical in their forward math.
    """
    for layer in range(n_layers):                        # static unroll
        w = w_ref[layer]                                 # (P+H, 4H)
        # one coarse MXU work unit per layer: all four gates at once,
        # split as x-part + h-part to skip an in-loop concatenate
        gates = (
            jax.lax.dot_general(inp, w[:p_width],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32)
            + jax.lax.dot_general(h_scr[layer], w[p_width:],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=F32)
            + b_ref[layer].astype(F32))
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_new = (jax.nn.sigmoid(f) * c_scr[layer]
                 + jax.nn.sigmoid(i) * jnp.tanh(g))
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        c_scr[layer] = c_new
        h_scr[layer] = h_new
        hidden = h_new.shape[-1]
        inp = h_new if p_width == hidden else \
            jnp.pad(h_new, ((0, 0), (0, p_width - hidden)))


def _seq_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, c_scr, h_scr,
                *, n_layers: int, seq_len: int, p_width: int):
    """One batch tile runs the whole (T x L) recurrence from VMEM.

    x_ref: (T, bm, P) time-major input tile; w_ref: (L, P+H, 4H);
    b_ref: (L, 4H); c_scr/h_scr: (L, bm, H) f32 VMEM scratch that IS the
    paper's preallocated state — written every step, never leaving VMEM.
    """
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, carry):
        inp = x_ref[pl.ds(t, 1)][0].astype(F32)          # (bm, P)
        _step_layers(inp, w_ref, b_ref, c_scr, h_scr, n_layers=n_layers,
                     p_width=p_width)
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


def _seq_traj_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, ct_ref,
                     ht_ref, c_scr, h_scr, *, n_layers: int, seq_len: int,
                     p_width: int):
    """Forward with residuals: same recurrence, but every step also writes
    the post-step (c, h) into the (T, L, bm, H) f32 trajectory outputs —
    the residual contract the reverse-sweep kernel (lstm_seq_bwd) consumes.
    Still ONE dispatch; the trajectory rows stream out of the same loop.
    """
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, carry):
        inp = x_ref[pl.ds(t, 1)][0].astype(F32)          # (bm, P)
        _step_layers(inp, w_ref, b_ref, c_scr, h_scr, n_layers=n_layers,
                     p_width=p_width)
        ct_ref[pl.ds(t, 1)] = c_scr[...][None]
        ht_ref[pl.ds(t, 1)] = h_scr[...][None]
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _lstm_seq_call(w: jax.Array, b: jax.Array, x: jax.Array,
                   block_b: int, interpret: bool
                   ) -> tuple[jax.Array, jax.Array]:
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    out = jax.ShapeDtypeStruct((L, B, H), x.dtype)
    kernel = functools.partial(_seq_kernel, n_layers=L, seq_len=T,
                               p_width=P)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(B, bm),),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_shape=[out, out],
        scratch_shapes=[
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
        ],
        interpret=interpret,
    )(xt, w, b)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def _lstm_seq_traj_call(w: jax.Array, b: jax.Array, x: jax.Array,
                        block_b: int, interpret: bool
                        ) -> tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array]:
    """Trajectory-emitting forward: (c, h, c_traj, h_traj), still ONE
    dispatch.  Trajectories are (T, L, B, H) f32 — the residual contract."""
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    out = jax.ShapeDtypeStruct((L, B, H), x.dtype)
    traj = jax.ShapeDtypeStruct((T, L, B, H), F32)
    kernel = functools.partial(_seq_traj_kernel, n_layers=L, seq_len=T,
                               p_width=P)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(B, bm),),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
        ],
        out_shape=[out, out, traj, traj],
        scratch_shapes=[
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
        ],
        interpret=interpret,
    )(xt, w, b)


# ---------------------------------------------------------------------------
# Differentiable entry point
# ---------------------------------------------------------------------------
#: bwd_block_b sentinel: "no viable backward tile — use the oracle VJP".
ORACLE_BWD = 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _lstm_seq(w, b, x, block_b, bwd_block_b, interpret):
    return _lstm_seq_call(w, b, x, block_b, interpret)


def _lstm_seq_fwd(w, b, x, block_b, bwd_block_b, interpret):
    if bwd_block_b == ORACLE_BWD:
        # backward working set does not fit VMEM: plain forward, oracle VJP
        return _lstm_seq_call(w, b, x, block_b, interpret), (w, b, x)
    c, h, ct, ht = _lstm_seq_traj_call(w, b, x, bwd_block_b, interpret)
    return (c, h), (w, b, x, ct, ht)


def _lstm_seq_bwd(block_b, bwd_block_b, interpret, residuals, cotangents):
    if bwd_block_b == ORACLE_BWD:
        from repro.kernels import ref
        w, b, x = residuals
        _, vjp = jax.vjp(ref.lstm_seq, w, b, x)
        return vjp(cotangents)
    from repro.kernels import lstm_seq_bwd as bwd_lib
    w, b, x, ct, ht = residuals
    dc, dh = cotangents
    return bwd_lib.lstm_seq_bwd(w, b, x, ct, ht, dc, dh,
                                block_b=bwd_block_b, interpret=interpret)


_lstm_seq.defvjp(_lstm_seq_fwd, _lstm_seq_bwd)


def lstm_seq(w: jax.Array, b: jax.Array, x: jax.Array, *,
             block_b: int | None = None, bwd_block_b: int | None = None,
             interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Whole-sequence stacked LSTM in ONE kernel dispatch.

    w: (L, P+H, 4H) stacked gate weights (stack_params); b: (L, 4H);
    x: (B, T, P) input zero-padded to width P (pad_input).
    Returns final (c, h), each (L, B, H).  Oracle: kernels/ref.lstm_seq.

    ``bwd_block_b`` is the batch tile for the TRAINING path (the
    trajectory-emitting forward + the reverse-sweep kernel, each ONE
    dispatch); defaults to ``choose_batch_block(mode="bwd")``.  Pass
    ``ORACLE_BWD`` (0) to force the oracle-VJP fallback — which is also what
    happens automatically when no backward tile fits the VMEM budget.
    Inference through ``lstm_seq`` never pays for residuals: the trajectory
    variant only runs under differentiation (custom_vjp fwd rule).
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, xw = x.shape
    assert w.shape[1] == P + H and xw == P, (w.shape, x.shape)
    dtype_bytes = jnp.dtype(x.dtype).itemsize
    w_bytes = jnp.dtype(w.dtype).itemsize
    if block_b is None:
        block_b = choose_batch_block(
            B, T, L, P, H, dtype_bytes=dtype_bytes, w_dtype_bytes=w_bytes)
        if block_b is None:
            raise ValueError(
                f"sequence-resident working set (L={L}, P+H={P + H}, "
                f"4H={4 * H}, T={T}) exceeds the VMEM budget even at "
                "batch tile 1; use the per-cell fallback "
                "(core/lstm.forward_fused_seq routes this automatically)")
    if bwd_block_b is None:
        bwd_block_b = choose_batch_block(
            B, T, L, P, H, dtype_bytes=dtype_bytes, w_dtype_bytes=w_bytes,
            mode="bwd") or ORACLE_BWD
    return _lstm_seq(w, b, x, block_b, bwd_block_b, interpret)
