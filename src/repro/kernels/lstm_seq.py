"""Sequence-resident fused stacked-LSTM Pallas kernel.

MobiRNN's §3.2-3.3 lesson is that RNN latency on a constrained accelerator
is won by coarsening work units and keeping state resident.  The per-cell
kernel (kernels/lstm_cell.py) coarsens WITHIN a timestep but still launches
one ``pallas_call`` per cell per step — T x L dispatches, with the gate
weights re-read from HBM every time.  This kernel moves the ENTIRE time loop
inside one ``pallas_call``:

* grid over batch tiles — batch rows are independent, so they tile freely;
* ``jax.lax.fori_loop`` over T inside the kernel body;
* stacked per-layer weights ``(L, P+H, 4H)`` loaded into VMEM once and
  reused across all T timesteps (P = max(input_dim, H), rows zero-padded so
  every layer shares one shape — same trick as wavefront.stack_homogeneous);
* ``(c, h)`` carried in VMEM scratch, so recurrent state never round-trips
  HBM between steps — the paper's preallocation bound realised at kernel
  level.

Dispatch count is O(1) in sequence length instead of O(T*L)
(``analysis.count_kernel_dispatches`` asserts this in tests and benchmarks).

Why the grid does NOT tile the hidden dimension: h_t feeds the gates of
step t+1 across ALL hidden columns, so a hidden tile would need the other
tiles' h before its own time loop could advance — the recurrence makes
hidden tiles non-independent.  When the ``(L, P+H, 4H)`` weight stack (plus
state and the input block) exceeds the VMEM budget, ``choose_batch_block``
returns None and callers fall back to the per-cell kernel, which DOES tile
hidden because it re-synchronises through HBM every step.  See
core/lstm.py for the four-plan decision table.

Time streaming: the recurrence is sequential in T, but the INPUT is not —
so past a modest T the kernel does not need the whole ``(T, bm, P)`` block
resident.  With ``time_chunk=tc`` the input stays in HBM
(``pltpu.ANY``) and the kernel streams it through two ``(tc, bm, P)`` VMEM
buffers with async copies, prefetching chunk k+1 while chunk k computes
(the classic double-buffer pipeline; pallas_guide §Double Buffering —
exactly the remedy Lee et al. and Rezk et al. prescribe for RNN state on
constrained accelerators).  The trajectory-emitting forward additionally
streams its ``(tc, L, bm, H)`` residual chunks OUT through two staging
buffers, so VMEM residency is O(tc) — not O(T) — in every training-path
dispatch while weights and the ``(c, h)`` carries stay resident across
chunks.  Chunking changes data movement only: the per-step math is the
shared ``_step_layers`` body, so chunked and unchunked kernels are
bit-identical (tests/test_lstm_seq.py asserts it).

Autodiff: ``pallas_call`` has no VJP rule, so ``lstm_seq`` wraps the kernel
in a ``jax.custom_vjp``.  Under differentiation the forward runs a
trajectory-emitting variant of the kernel (same math, same single dispatch)
that additionally writes the per-step ``(c, h)`` trajectory — two
``(T, L, B, H)`` f32 residuals — and the backward runs the whole
reverse-time BPTT sweep in ONE kernel dispatch (kernels/lstm_seq_bwd.py):
gates are recomputed from the stored trajectory, ``dw``/``db`` accumulate in
f32 VMEM scratch across batch tiles, and the ``(dc, dh)`` carries never
leave VMEM.  When ``choose_batch_block(mode="bwd")`` finds no batch tile
whose backward working set (~3x the forward one: trajectories + dw scratch
+ dx block ride along) fits the budget, the backward falls back to
differentiating the pure-jnp oracle (kernels/ref.lstm_seq) — numerically
identical forward math, so gradients stay exact either way
(tests/test_lstm_seq.py checks both paths against end-to-end reference
grads).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization, tiling

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Parameter stacking — one (L, P+H, 4H) weight block the kernel loads once.
# ---------------------------------------------------------------------------
def stack_params(layers: list[dict], hidden: int
                 ) -> tuple[jax.Array, jax.Array, int]:
    """Stack per-layer cell params to (L, P+H, 4H) / (L, 4H).

    ``layers`` are PLAIN (un-annotated) per-layer dicts with "w" of shape
    (in_dim_i + H, 4H).  Rows are rearranged to [input rows | h rows] with
    the input rows zero-padded to P = max(max_i in_dim_i, H), so one VMEM
    block serves every layer; callers zero-pad the raw input to width P
    (pad_input).  Padding rows multiply padded zeros — exactly equivalent.
    Returns (w_stack, b_stack, P).
    """
    in_dims = [layer["w"].shape[0] - hidden for layer in layers]
    p_width = max(max(in_dims), hidden)
    ws, bs = [], []
    for layer, in_dim in zip(layers, in_dims):
        w = layer["w"]
        if in_dim < p_width:
            pad = jnp.zeros((p_width - in_dim, 4 * hidden), w.dtype)
            w = jnp.concatenate([w[:in_dim], pad, w[in_dim:]], axis=0)
        ws.append(w)
        bs.append(layer["b"])
    return jnp.stack(ws), jnp.stack(bs), p_width


def pad_input(x: jax.Array, p_width: int) -> jax.Array:
    """Zero-pad x: (B, T, D) to (B, T, P) to match the stacked weight rows."""
    d = x.shape[-1]
    if d == p_width:
        return x
    return jnp.pad(x, ((0, 0), (0, 0), (0, p_width - d)))


# ---------------------------------------------------------------------------
# VMEM budget — the MobiRNN packing rule applied to the whole sequence.
# ---------------------------------------------------------------------------
class SeqBlocks(NamedTuple):
    """The fused kernel's tiling decision: batch tile x time residency.

    ``time_chunk=None`` means the whole (T, bm, P) input block (and, in bwd,
    the whole trajectories) stay VMEM-resident for the grid step — the
    fastest layout when it fits.  An integer ``time_chunk=tc`` means the
    kernel streams the time axis through double-buffered (tc, bm, P) VMEM
    buffers instead, making residency O(tc) in sequence length.

    Presents the family-generic ``core/tiling.TilePlan`` interface:
    ``batch_tile`` is this family's ``block_b``; ``time_chunk`` is already
    the shared spelling."""
    block_b: int
    time_chunk: int | None = None

    @property
    def batch_tile(self) -> int:
        return self.block_b


def working_set_bytes(seq_len: int, n_layers: int, p_width: int, hidden: int,
                      block_b: int, dtype_bytes: int = 4,
                      w_dtype_bytes: int | None = None,
                      mode: str = "fwd",
                      time_chunk: int | None = None,
                      quantized: bool = False) -> int:
    """Kernel working set for one grid step, per phase.

    ``mode="fwd"`` sizes the inference forward: stacked weights + the batch
    tile's input residency + f32 (c,h) scratch + output blocks.

    ``mode="bwd"`` sizes the TRAINING working set — the reverse-sweep kernel
    (kernels/lstm_seq_bwd.py), which strictly dominates the
    trajectory-emitting forward that feeds it, so one number gates both
    dispatches.  On top of the forward set it holds the (T, L, bm, H) f32
    trajectory residuals (or their double-buffered chunk windows), the f32
    dw/db accumulator scratch (a second weight-stack-sized block), the
    dw/db output blocks, the dx residency (mirroring the input) and the
    (dc, dh) carry scratch — roughly 3x the forward working set at the
    paper's shapes.

    ``time_chunk=None`` sizes the whole-T-resident layout: the input block
    is (T, bm, P) and the bwd trajectories are fully resident — O(T) VMEM.
    ``time_chunk=tc`` sizes the STREAMED layout: two (tc, bm, P) input
    buffers (prefetch + compute), and in bwd two (tc+1)-row windows per
    trajectory plus a mirrored two-slot dx staging — O(tc) VMEM; weights,
    carries, and dw/db accumulators stay resident across chunks either way.

    ``dtype_bytes`` sizes activations/outputs; ``w_dtype_bytes`` sizes the
    weight stack (defaults to ``dtype_bytes`` — pass it explicitly under
    mixed precision, e.g. bf16 activations over f32 parameters).

    ``quantized=True`` sizes the int8-weight plan (``fused_seq_q8``): the
    weight stack is 1 byte/weight (unless ``w_dtype_bytes`` overrides), the
    f32 per-channel scales ride along with the f32 biases, PLUS one
    f32 (P+H, 4H) slab for the active layer's on-the-fly dequantized block
    (``_step_layers``/``_unwind_step`` cast ``w_ref[layer]`` to f32 before
    the matmuls — a live weight-layer-sized temporary the int8 residency
    saving must pay for), and in ``bwd`` the dw/db OUTPUTS are f32
    (straight-through gradients land on the f32 master weights, never on
    the int8 stack) — the f32 dw/db accumulator scratch is unchanged
    either way."""
    ws = tiling.WorkingSet(mode)
    wb = tiling.weight_dtype_bytes(dtype_bytes, w_dtype_bytes, quantized)
    w_count = n_layers * (p_width + hidden) * 4 * hidden
    b_count = n_layers * 4 * hidden
    weights = w_count * wb
    if quantized:
        biases = b_count * 4 * 2        # f32 bias + f32 per-channel scales
        weights += (p_width + hidden) * 4 * hidden * 4   # dequant temporary
    else:
        biases = b_count * wb
    ws.add("weights", weights).add("biases", biases)
    x_rows = tiling.streamed_rows(seq_len, time_chunk)
    x_block = block_b * x_rows * p_width * dtype_bytes
    ws.add("x_block", x_block)
    ws.add("state", 2 * n_layers * block_b * hidden * 4)     # f32 scratch
    ws.add("outs", 2 * n_layers * block_b * hidden * dtype_bytes)
    if time_chunk is None:
        traj_rows = seq_len                                  # resident
    else:                                         # 2 slots x (tc+1)-row win
        traj_rows = tiling.STREAM_SLOTS * tiling.bwd_window_rows(
            seq_len, time_chunk)
    ws.add("traj", 2 * traj_rows * n_layers * block_b * hidden * 4,
           bwd_only=True)
    ws.add("dw_scratch", (w_count + b_count) * 4, bwd_only=True)  # f32 accum
    if quantized:
        dw_out = (w_count + b_count) * 4         # f32 master-weight grads
    else:
        dw_out = weights + biases                              # param dtype
    ws.add("dw_out", dw_out, bwd_only=True)
    ws.add("dx_block", x_block, bwd_only=True)   # dx mirrors x residency
    # (dc, dh) carries reuse `state`; the final-state cotangent blocks:
    ws.add("cots", 2 * n_layers * block_b * hidden * dtype_bytes,
           bwd_only=True)
    return ws.total()


def choose_batch_block(batch: int, seq_len: int, n_layers: int,
                       p_width: int, hidden: int, dtype_bytes: int = 4,
                       vmem_budget: int | None = None,
                       w_dtype_bytes: int | None = None,
                       mode: str = "fwd",
                       allow_chunk: bool = True,
                       quantized: bool = False) -> SeqBlocks | None:
    """Pick the (batch tile, time residency), or None when not viable.

    Seeds the batch tile from factorization.choose_block on the per-step
    gate matmul (B, P+H) x (P+H, 4H) — the coarsest MXU-aligned block — then
    searches the joint ``(block_b, time_chunk)`` surface via the shared
    ``core/tiling.joint_search`` in MobiRNN coarseness order:

    1. whole-T residency at the current batch tile (``time_chunk=None`` —
       no streaming machinery at all) when it fits;
    2. otherwise STREAM the time axis: a halving sweep from ``tc = T//2``
       down to 1 takes the first (coarsest) chunk whose double-buffered
       working set fits — this keeps the batch tile coarse (full MXU rows,
       one grid step) and hides the input DMA behind compute instead of
       multiplying grid steps;
    3. only when even ``tc=1`` does not fit, halve the batch tile and
       retry — shrinking bm shrinks the weight-independent terms too.

    ``mode="bwd"`` sizes the TRAINING working set instead (trajectory
    residuals + gradient accumulators, see ``working_set_bytes``) — under
    ``jax.grad`` this is the number that matters, and it is ~3x the forward
    one, so a tiling that is fine for inference can be non-viable for
    training.  Returns None only when even ``(bm=1, tc=1)`` cannot fit —
    i.e. the weight stack plus its gradient accumulators themselves blow
    VMEM (large H/L); long T alone is no longer a reason to fall back.
    Callers then route to the per-cell kernel (fwd) or the oracle VJP
    (bwd).  ``allow_chunk=False`` restores the pre-streaming decision
    surface (whole-T residency or bust) — used by benchmarks to show the
    cliff the pipeline removes.  ``quantized=True`` sizes the int8-weight
    plan (1 byte/weight + f32 scales, f32 dw/db outs in bwd — see
    ``working_set_bytes``): with the dominant weight term quartered, the
    same coarseness search admits whole-T residency deeper into T and
    coarser tiles at budgets where f32 weights force streaming or fail.
    """
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget

    def fits(bm: int, tc: int | None) -> bool:
        return working_set_bytes(seq_len, n_layers, p_width, hidden, bm,
                                 dtype_bytes, w_dtype_bytes, mode=mode,
                                 time_chunk=tc, quantized=quantized) <= budget

    seed, _, _ = factorization.choose_block(
        batch, 4 * hidden, p_width + hidden, bytes_per_elem=dtype_bytes,
        vmem_budget=budget)
    found = tiling.joint_search(batch, seq_len, fits, seed_batch_tile=seed,
                                allow_chunk=allow_chunk)
    return None if found is None else SeqBlocks(*found)


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------
def _step_layers(inp, w_ref, b_ref, c_scr, h_scr, *, n_layers: int,
                 p_width: int, s_ref=None) -> None:
    """Advance all L layers one timestep, updating (c, h) scratch in place.

    ``inp``: (bm, P) f32 — this step's (padded) input.  Shared by the plain,
    trajectory-emitting, and backward-recompute kernel bodies so the three
    dispatches stay bit-identical in their forward math.

    ``s_ref`` (optional): (L, 4H) f32 per-output-channel scales — the int8
    path (``fused_seq_q8``).  The weights then live in VMEM as int8 and are
    dequantized ON THE FLY: cast to f32 for the gate matmuls and the
    per-channel scale folded into the pre-activations afterwards
    ((x @ wq) * s == x @ (wq * s) — exact in reals, an fp-rounding error
    band vs the dequantize oracle).  The dequantized block is a per-layer
    f32 temporary — one (P+H, 4H) slab at a time, which
    ``working_set_bytes(quantized=True)`` counts on top of the resident
    1-byte stack.
    """
    for layer in range(n_layers):                        # static unroll
        w = w_ref[layer]                                 # (P+H, 4H)
        if s_ref is not None:
            w = w.astype(F32)                            # int8 -> f32
        # one coarse MXU work unit per layer: all four gates at once,
        # split as x-part + h-part to skip an in-loop concatenate
        gates = (
            jax.lax.dot_general(inp, w[:p_width],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=F32)
            + jax.lax.dot_general(h_scr[layer], w[p_width:],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=F32))
        if s_ref is not None:
            gates = gates * s_ref[layer].astype(F32)     # fold channel scale
        gates = gates + b_ref[layer].astype(F32)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_new = (jax.nn.sigmoid(f) * c_scr[layer]
                 + jax.nn.sigmoid(i) * jnp.tanh(g))
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        c_scr[layer] = c_new
        h_scr[layer] = h_new
        hidden = h_new.shape[-1]
        inp = h_new if p_width == hidden else \
            jnp.pad(h_new, ((0, 0), (0, p_width - hidden)))


def _seq_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, c_scr, h_scr,
                *, n_layers: int, seq_len: int, p_width: int, s_ref=None):
    """One batch tile runs the whole (T x L) recurrence from VMEM.

    x_ref: (T, bm, P) time-major input tile; w_ref: (L, P+H, 4H);
    b_ref: (L, 4H); c_scr/h_scr: (L, bm, H) f32 VMEM scratch that IS the
    paper's preallocated state — written every step, never leaving VMEM.
    ``s_ref``: (L, 4H) f32 per-channel scales when w_ref is int8 (q8 plan).
    """
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, carry):
        inp = x_ref[pl.ds(t, 1)][0].astype(F32)          # (bm, P)
        _step_layers(inp, w_ref, b_ref, c_scr, h_scr, n_layers=n_layers,
                     p_width=p_width, s_ref=s_ref)
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


def _seq_q8_kernel(x_ref, w_ref, s_ref, b_ref, c_out_ref, h_out_ref, c_scr,
                   h_scr, *, n_layers: int, seq_len: int, p_width: int):
    """Int8-weight forward: the same body with the (L, 4H) f32 scales as an
    extra input ref and the weight stack VMEM-resident as int8 (4x smaller
    than the f32 plan's dominant term)."""
    _seq_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, c_scr, h_scr,
                n_layers=n_layers, seq_len=seq_len, p_width=p_width,
                s_ref=s_ref)


def _seq_traj_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, ct_ref,
                     ht_ref, c_scr, h_scr, *, n_layers: int, seq_len: int,
                     p_width: int, s_ref=None):
    """Forward with residuals: same recurrence, but every step also writes
    the post-step (c, h) into the (T, L, bm, H) f32 trajectory outputs —
    the residual contract the reverse-sweep kernel (lstm_seq_bwd) consumes.
    Still ONE dispatch; the trajectory rows stream out of the same loop.
    """
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, carry):
        inp = x_ref[pl.ds(t, 1)][0].astype(F32)          # (bm, P)
        _step_layers(inp, w_ref, b_ref, c_scr, h_scr, n_layers=n_layers,
                     p_width=p_width, s_ref=s_ref)
        ct_ref[pl.ds(t, 1)] = c_scr[...][None]
        ht_ref[pl.ds(t, 1)] = h_scr[...][None]
        return carry

    jax.lax.fori_loop(0, seq_len, step, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


def _seq_traj_q8_kernel(x_ref, w_ref, s_ref, b_ref, c_out_ref, h_out_ref,
                        ct_ref, ht_ref, c_scr, h_scr, *, n_layers: int,
                        seq_len: int, p_width: int):
    """Int8-weight trajectory-emitting forward (q8 training-path fwd)."""
    _seq_traj_kernel(x_ref, w_ref, b_ref, c_out_ref, h_out_ref, ct_ref,
                     ht_ref, c_scr, h_scr, n_layers=n_layers,
                     seq_len=seq_len, p_width=p_width, s_ref=s_ref)


# ---------------------------------------------------------------------------
# Time-chunked, double-buffered kernel bodies: x stays in HBM (pltpu.ANY)
# and streams through two (tc, bm, P) VMEM buffers; trajectory residuals
# stream OUT through two staging buffers.  Helpers shared by fwd + traj.
# ---------------------------------------------------------------------------
def _x_chunk_dma(x_hbm, xbuf, xsem, slot, k, *, tc: int, seq_len: int,
                 bm: int, ib):
    """Async copy of input chunk k into buffer ``slot``.

    The copy window is static-size ``tc`` rows with a CLAMPED start
    (min(k*tc, T-tc)) so the tail chunk of a non-dividing T stays in
    bounds; steps index the buffer at ``t - start``, and rows below the
    chunk (duplicates of already-consumed steps) are simply never read.
    ``ib`` is the batch-tile id, captured ONCE at kernel top — calling
    ``pl.program_id`` inside a ``pl.when`` branch does not lower.
    """
    src = jnp.minimum(k * tc, seq_len - tc)
    return pltpu.make_async_copy(
        x_hbm.at[pl.ds(src, tc), pl.ds(ib * bm, bm)],
        xbuf.at[slot], xsem.at[slot])


def _seq_chunked_kernel(x_hbm, w_ref, b_ref, c_out_ref, h_out_ref,
                        xbuf, c_scr, h_scr, xsem,
                        *, n_layers: int, seq_len: int, p_width: int,
                        tc: int, nc: int, s_ref=None):
    """Forward with O(tc) input residency: same recurrence as ``_seq_kernel``
    but the (T, bm, P) block never materialises — chunk k+1 prefetches while
    chunk k computes.  x_hbm: (T, Bp, P) in HBM (batch padded to the tile
    grid); xbuf: (2, tc, bm, P) VMEM; weights and (c, h) stay resident.
    """
    bm = c_scr.shape[1]
    ib = pl.program_id(0)
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def dma(slot, k):
        return _x_chunk_dma(x_hbm, xbuf, xsem, slot, k, tc=tc,
                            seq_len=seq_len, bm=bm, ib=ib)

    dma(0, 0).start()                                    # warm-up

    def chunk(k, carry):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < nc)
        def _prefetch():
            dma(jax.lax.rem(k + 1, 2), k + 1).start()

        dma(slot, k).wait()
        src = jnp.minimum(k * tc, seq_len - tc)

        def step(i, c2):
            t = k * tc + i

            @pl.when(t < seq_len)                        # tail-chunk guard
            def _advance():
                inp = xbuf[slot, t - src].astype(F32)    # (bm, P)
                _step_layers(inp, w_ref, b_ref, c_scr, h_scr,
                             n_layers=n_layers, p_width=p_width,
                             s_ref=s_ref)
            return c2

        jax.lax.fori_loop(0, tc, step, 0)
        return carry

    jax.lax.fori_loop(0, nc, chunk, 0)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


def _seq_chunked_q8_kernel(x_hbm, w_ref, s_ref, b_ref, c_out_ref, h_out_ref,
                           xbuf, c_scr, h_scr, xsem,
                           *, n_layers: int, seq_len: int, p_width: int,
                           tc: int, nc: int):
    """Int8-weight streamed forward (scales ride with the resident stack)."""
    _seq_chunked_kernel(x_hbm, w_ref, b_ref, c_out_ref, h_out_ref,
                        xbuf, c_scr, h_scr, xsem, n_layers=n_layers,
                        seq_len=seq_len, p_width=p_width, tc=tc, nc=nc,
                        s_ref=s_ref)


def _seq_traj_chunked_kernel(x_hbm, w_ref, b_ref, c_out_ref, h_out_ref,
                             ct_hbm, ht_hbm,
                             xbuf, ctb, htb, c_scr, h_scr,
                             xsem, csem, hsem,
                             *, n_layers: int, seq_len: int, p_width: int,
                             tc: int, nc: int, s_ref=None):
    """Trajectory-emitting forward with O(tc) residency on BOTH sides: input
    chunks stream in, (tc, L, bm, H) trajectory chunks stream out through
    two staging buffers each.  ct_hbm/ht_hbm are (nc*tc, L, Bp, H) in HBM —
    time-padded so every chunk's output window is disjoint (the wrapper
    slices [:T]); a staging slot is reused only after its previous flight
    completes (the k-2 wait below).
    """
    bm = c_scr.shape[1]
    ib = pl.program_id(0)
    c_scr[...] = jnp.zeros_like(c_scr)
    h_scr[...] = jnp.zeros_like(h_scr)

    def dma_in(slot, k):
        return _x_chunk_dma(x_hbm, xbuf, xsem, slot, k, tc=tc,
                            seq_len=seq_len, bm=bm, ib=ib)

    def dma_out(buf, hbm, sem, slot, k):
        return pltpu.make_async_copy(
            buf.at[slot],
            hbm.at[pl.ds(k * tc, tc), :, pl.ds(ib * bm, bm)],
            sem.at[slot])

    dma_in(0, 0).start()                                 # warm-up

    def chunk(k, carry):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < nc)
        def _prefetch():
            dma_in(jax.lax.rem(k + 1, 2), k + 1).start()

        dma_in(slot, k).wait()
        # the staging slot's previous flight (chunk k-2) must land before
        # this chunk overwrites it
        @pl.when(k >= 2)
        def _reclaim():
            dma_out(ctb, ct_hbm, csem, slot, k - 2).wait()
            dma_out(htb, ht_hbm, hsem, slot, k - 2).wait()

        src = jnp.minimum(k * tc, seq_len - tc)

        def step(i, c2):
            t = k * tc + i

            @pl.when(t < seq_len)                        # tail-chunk guard
            def _advance():
                inp = xbuf[slot, t - src].astype(F32)    # (bm, P)
                _step_layers(inp, w_ref, b_ref, c_scr, h_scr,
                             n_layers=n_layers, p_width=p_width,
                             s_ref=s_ref)
                ctb[slot, i] = c_scr[...]
                htb[slot, i] = h_scr[...]
            return c2

        jax.lax.fori_loop(0, tc, step, 0)
        dma_out(ctb, ct_hbm, csem, slot, k).start()
        dma_out(htb, ht_hbm, hsem, slot, k).start()
        return carry

    jax.lax.fori_loop(0, nc, chunk, 0)
    # drain the (at most two) outstanding trajectory flights
    dma_out(ctb, ct_hbm, csem, jax.lax.rem(nc - 1, 2), nc - 1).wait()
    dma_out(htb, ht_hbm, hsem, jax.lax.rem(nc - 1, 2), nc - 1).wait()

    @pl.when(nc >= 2)
    def _drain_prev():
        dma_out(ctb, ct_hbm, csem, jax.lax.rem(nc - 2, 2), nc - 2).wait()
        dma_out(htb, ht_hbm, hsem, jax.lax.rem(nc - 2, 2), nc - 2).wait()

    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)


def _seq_traj_chunked_q8_kernel(x_hbm, w_ref, s_ref, b_ref, c_out_ref,
                                h_out_ref, ct_hbm, ht_hbm,
                                xbuf, ctb, htb, c_scr, h_scr,
                                xsem, csem, hsem,
                                *, n_layers: int, seq_len: int, p_width: int,
                                tc: int, nc: int):
    """Int8-weight streamed trajectory-emitting forward."""
    _seq_traj_chunked_kernel(x_hbm, w_ref, b_ref, c_out_ref, h_out_ref,
                             ct_hbm, ht_hbm, xbuf, ctb, htb, c_scr, h_scr,
                             xsem, csem, hsem, n_layers=n_layers,
                             seq_len=seq_len, p_width=p_width, tc=tc, nc=nc,
                             s_ref=s_ref)


def _pad_batch(a: jax.Array, axis: int, padded: int) -> jax.Array:
    """Zero-pad ``axis`` of ``a`` to length ``padded`` (manual-DMA kernels
    address batch tiles themselves, so the tile grid must divide exactly —
    garbage rows are masked/sliced, never computed into shared state)."""
    if a.shape[axis] == padded:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, padded - a.shape[axis])
    return jnp.pad(a, pads)


@functools.partial(jax.jit,
                   static_argnames=("block_b", "time_chunk", "interpret"))
def _lstm_seq_call(w: jax.Array, b: jax.Array, x: jax.Array,
                   block_b: int, time_chunk: int | None, interpret: bool,
                   scales: jax.Array | None = None
                   ) -> tuple[jax.Array, jax.Array]:
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    if time_chunk is not None:
        return _lstm_seq_chunked_call(w, b, xt, bm, min(time_chunk, T),
                                      interpret, scales=scales)
    out = jax.ShapeDtypeStruct((L, B, H), x.dtype)
    if scales is None:
        kernel = functools.partial(_seq_kernel, n_layers=L, seq_len=T,
                                   p_width=P)
        s_in, s_spec = (), ()
    else:
        kernel = functools.partial(_seq_q8_kernel, n_layers=L, seq_len=T,
                                   p_width=P)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(B, bm),),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_shape=[out, out],
        scratch_shapes=[
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
        ],
        interpret=interpret,
    )(xt, w, *s_in, b)


def _lstm_seq_chunked_call(w, b, xt, bm: int, tc: int, interpret: bool,
                           scales=None) -> tuple[jax.Array, jax.Array]:
    """Streamed forward: x lives in HBM, VMEM holds O(tc) of it."""
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    T, B, _ = xt.shape
    n_tiles = pl.cdiv(B, bm)
    Bp = n_tiles * bm
    nc = pl.cdiv(T, tc)
    xt = _pad_batch(xt, 1, Bp)
    out = jax.ShapeDtypeStruct((L, Bp, H), xt.dtype)
    if scales is None:
        kernel = functools.partial(_seq_chunked_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, nc=nc)
        s_in, s_spec = (), ()
    else:
        kernel = functools.partial(_seq_chunked_q8_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, nc=nc)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
    c, h = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),        # x streams manually
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
        ],
        out_shape=[out, out],
        scratch_shapes=[
            pltpu.VMEM((2, tc, bm, P), xt.dtype),        # double buffer
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(xt, w, *s_in, b)
    return c[:, :B], h[:, :B]


@functools.partial(jax.jit,
                   static_argnames=("block_b", "time_chunk", "interpret"))
def _lstm_seq_traj_call(w: jax.Array, b: jax.Array, x: jax.Array,
                        block_b: int, interpret: bool,
                        time_chunk: int | None = None,
                        scales: jax.Array | None = None
                        ) -> tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array]:
    """Trajectory-emitting forward: (c, h, c_traj, h_traj), still ONE
    dispatch.  Trajectories are (T, L, B, H) f32 — the residual contract,
    identical (bit-for-bit) whether the kernel holds T resident
    (``time_chunk=None``) or streams it in chunks."""
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, _ = x.shape
    bm = min(block_b, B)
    xt = jnp.swapaxes(x, 0, 1)                           # (T, B, P)
    if time_chunk is not None:
        return _lstm_seq_traj_chunked_call(w, b, xt, bm, min(time_chunk, T),
                                           interpret, scales=scales)
    out = jax.ShapeDtypeStruct((L, B, H), x.dtype)
    traj = jax.ShapeDtypeStruct((T, L, B, H), F32)
    if scales is None:
        kernel = functools.partial(_seq_traj_kernel, n_layers=L, seq_len=T,
                                   p_width=P)
        s_in, s_spec = (), ()
    else:
        kernel = functools.partial(_seq_traj_q8_kernel, n_layers=L,
                                   seq_len=T, p_width=P)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(B, bm),),
        in_specs=[
            pl.BlockSpec((T, bm, P), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
            pl.BlockSpec((T, L, bm, H), lambda ib: (0, 0, ib, 0)),
        ],
        out_shape=[out, out, traj, traj],
        scratch_shapes=[
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
        ],
        interpret=interpret,
    )(xt, w, *s_in, b)


def _lstm_seq_traj_chunked_call(w, b, xt, bm: int, tc: int, interpret: bool,
                                scales=None
                                ) -> tuple[jax.Array, jax.Array, jax.Array,
                                           jax.Array]:
    """Streamed trajectory forward: O(tc) VMEM for input AND residuals."""
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    T, B, _ = xt.shape
    n_tiles = pl.cdiv(B, bm)
    Bp = n_tiles * bm
    nc = pl.cdiv(T, tc)
    Tp = nc * tc              # time-padded so chunk windows are disjoint
    xt = _pad_batch(xt, 1, Bp)
    out = jax.ShapeDtypeStruct((L, Bp, H), xt.dtype)
    traj = jax.ShapeDtypeStruct((Tp, L, Bp, H), F32)
    if scales is None:
        kernel = functools.partial(_seq_traj_chunked_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, nc=nc)
        s_in, s_spec = (), ()
    else:
        kernel = functools.partial(_seq_traj_chunked_q8_kernel, n_layers=L,
                                   seq_len=T, p_width=P, tc=tc, nc=nc)
        s_in = (scales,)
        s_spec = (pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),)
    c, h, ct, ht = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),        # x streams manually
            pl.BlockSpec((L, P + H, 4 * H), lambda ib: (0, 0, 0)),
            *s_spec,
            pl.BlockSpec((L, 4 * H), lambda ib: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec((L, bm, H), lambda ib: (0, ib, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),        # traj streams out
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_shape=[out, out, traj, traj],
        scratch_shapes=[
            pltpu.VMEM((2, tc, bm, P), xt.dtype),        # x double buffer
            pltpu.VMEM((2, tc, L, bm, H), F32),          # c_traj staging
            pltpu.VMEM((2, tc, L, bm, H), F32),          # h_traj staging
            pltpu.VMEM((L, bm, H), F32),
            pltpu.VMEM((L, bm, H), F32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(xt, w, *s_in, b)
    return c[:, :B], h[:, :B], ct[:T, :, :B], ht[:T, :, :B]


# ---------------------------------------------------------------------------
# Differentiable entry point
# ---------------------------------------------------------------------------
#: bwd spec sentinel: "no viable backward tiling — use the oracle VJP".
ORACLE_BWD = 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _lstm_seq(w, b, x, fwd_spec, bwd_spec, interpret):
    return _lstm_seq_call(w, b, x, fwd_spec[0], fwd_spec[1], interpret)


def _lstm_seq_fwd(w, b, x, fwd_spec, bwd_spec, interpret):
    if bwd_spec == ORACLE_BWD:
        # backward working set does not fit VMEM: plain forward, oracle VJP
        return (_lstm_seq_call(w, b, x, fwd_spec[0], fwd_spec[1], interpret),
                (w, b, x))
    c, h, ct, ht = _lstm_seq_traj_call(w, b, x, bwd_spec[0], interpret,
                                       time_chunk=bwd_spec[1])
    return (c, h), (w, b, x, ct, ht)


def _lstm_seq_bwd(fwd_spec, bwd_spec, interpret, residuals, cotangents):
    if bwd_spec == ORACLE_BWD:
        from repro.kernels import ref
        w, b, x = residuals
        _, vjp = jax.vjp(ref.lstm_seq, w, b, x)
        return vjp(cotangents)
    from repro.kernels import lstm_seq_bwd as bwd_lib
    w, b, x, ct, ht = residuals
    dc, dh = cotangents
    return bwd_lib.lstm_seq_bwd(w, b, x, ct, ht, dc, dh,
                                block_b=bwd_spec[0], time_chunk=bwd_spec[1],
                                interpret=interpret)


_lstm_seq.defvjp(_lstm_seq_fwd, _lstm_seq_bwd)


# ---------------------------------------------------------------------------
# Int8-weight differentiable entry point (the `fused_seq_q8` plan).
#
# The primal takes the f32 MASTER weight stack; quantization (per-output-
# channel symmetric int8, kernels/ref.quantize_q8) happens inside the traced
# function with plain jnp ops — no extra kernel dispatch — so `value_and_grad`
# stays at exactly 2 pallas_calls (trajectory-emitting q8 forward + q8
# reverse sweep).  Gradients are STRAIGHT-THROUGH: the backward differentiates
# the forward the kernel actually ran (dequantized int8 weights) and hands dw
# to the master stack unchanged (d wdq / d w = identity), with the f32 dw/db
# accumulators of the sweep untouched by the weight dtype.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _lstm_seq_q8(w, b, x, fwd_spec, bwd_spec, interpret):
    from repro.kernels import ref
    wq, s = ref.quantize_q8(w)
    return _lstm_seq_call(wq, b, x, fwd_spec[0], fwd_spec[1], interpret,
                          scales=s)


def _lstm_seq_q8_fwd(w, b, x, fwd_spec, bwd_spec, interpret):
    from repro.kernels import ref
    wq, s = ref.quantize_q8(w)
    if bwd_spec == ORACLE_BWD:
        # backward working set does not fit VMEM: plain q8 forward, oracle
        # VJP over the dequantized weights (straight-through to the master)
        out = _lstm_seq_call(wq, b, x, fwd_spec[0], fwd_spec[1], interpret,
                             scales=s)
        return out, (wq, s, b, x)
    c, h, ct, ht = _lstm_seq_traj_call(wq, b, x, bwd_spec[0], interpret,
                                       time_chunk=bwd_spec[1], scales=s)
    return (c, h), (wq, s, b, x, ct, ht)


def _lstm_seq_q8_bwd(fwd_spec, bwd_spec, interpret, residuals, cotangents):
    from repro.kernels import ref
    if bwd_spec == ORACLE_BWD:
        wq, s, b, x = residuals
        _, vjp = jax.vjp(ref.lstm_seq, ref.dequantize_q8(wq, s), b, x)
        return vjp(cotangents)          # dw wrt dequantized weights (STE)
    from repro.kernels import lstm_seq_bwd as bwd_lib
    wq, s, b, x, ct, ht = residuals
    dc, dh = cotangents
    return bwd_lib.lstm_seq_bwd(wq, b, x, ct, ht, dc, dh,
                                block_b=bwd_spec[0], time_chunk=bwd_spec[1],
                                interpret=interpret, scales=s)


_lstm_seq_q8.defvjp(_lstm_seq_q8_fwd, _lstm_seq_q8_bwd)


def _resolve_specs(B: int, T: int, L: int, P: int, H: int, *,
                   dtype_bytes: int, w_dtype_bytes: int | None,
                   quantized: bool, block_b: int | None,
                   time_chunk: int | None, bwd_block_b: int | None,
                   bwd_time_chunk: int | None):
    """Shared ``(fwd_spec, bwd_spec)`` resolution for the f32 and q8 entry
    points: explicit tiles pin the layout, otherwise ``choose_batch_block``
    searches the (quantization-aware) joint surface.  Raises when even a
    (bm=1, tc=1) forward tiling cannot fit — callers route to the per-cell
    fallback (core/lstm automates this)."""
    if block_b is None:
        blocks = choose_batch_block(
            B, T, L, P, H, dtype_bytes=dtype_bytes,
            w_dtype_bytes=w_dtype_bytes, quantized=quantized)
        if blocks is None:
            raise ValueError(
                f"sequence-resident working set (L={L}, P+H={P + H}, "
                f"4H={4 * H}, quantized={quantized}) exceeds the VMEM "
                "budget even at batch tile 1 with tc=1 time streaming; use "
                "the per-cell fallback (core/lstm routes this "
                "automatically)")
        block_b = blocks.block_b
        if time_chunk is None:         # explicit time_chunk survives auto-bm
            time_chunk = blocks.time_chunk
    fwd_spec = (block_b, time_chunk)
    if bwd_block_b is None:
        bwd_blocks = choose_batch_block(
            B, T, L, P, H, dtype_bytes=dtype_bytes,
            w_dtype_bytes=w_dtype_bytes, mode="bwd", quantized=quantized)
        if bwd_blocks is None:
            bwd_spec = ORACLE_BWD
        elif bwd_time_chunk is not None:
            bwd_spec = (bwd_blocks.block_b, bwd_time_chunk)
        else:
            bwd_spec = tuple(bwd_blocks)
    elif bwd_block_b == ORACLE_BWD:
        bwd_spec = ORACLE_BWD
    else:
        bwd_spec = (bwd_block_b, bwd_time_chunk)
    return fwd_spec, bwd_spec


def lstm_seq_q8(w: jax.Array, b: jax.Array, x: jax.Array, *,
                block_b: int | None = None, time_chunk: int | None = None,
                bwd_block_b: int | None = None,
                bwd_time_chunk: int | None = None,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Whole-sequence stacked LSTM with int8-quantized weights, ONE dispatch.

    Same contract as ``lstm_seq`` (w is the f32 MASTER (L, P+H, 4H) stack;
    quantize/dequantize happen inside — per-output-channel symmetric int8,
    see kernels/ref.quantize_q8), but the kernels hold the weight stack in
    VMEM as int8 + (L, 4H) f32 scales — the dominant VMEM term quartered —
    so ``choose_batch_block(quantized=True)`` admits whole-T residency and
    coarse batch tiles at budgets where the f32 plan must stream or shrink.
    Oracle: kernels/ref.lstm_seq_q8 (dequantize-then-run), matched within an
    fp-rounding error band (the scale folds into the pre-activations); vs
    the UNQUANTIZED plans the contract is the documented int8 error band
    (tests/test_plan_equivalence.py).  Under ``jax.grad``: straight-through
    gradients via the q8 reverse sweep, still 2 dispatches per
    ``value_and_grad`` at any T.
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, xw = x.shape
    assert w.shape[1] == P + H and xw == P, (w.shape, x.shape)
    fwd_spec, bwd_spec = _resolve_specs(
        B, T, L, P, H, dtype_bytes=jnp.dtype(x.dtype).itemsize,
        w_dtype_bytes=None, quantized=True, block_b=block_b,
        time_chunk=time_chunk, bwd_block_b=bwd_block_b,
        bwd_time_chunk=bwd_time_chunk)
    return _lstm_seq_q8(w, b, x, fwd_spec, bwd_spec, interpret)


def lstm_seq(w: jax.Array, b: jax.Array, x: jax.Array, *,
             block_b: int | None = None, time_chunk: int | None = None,
             bwd_block_b: int | None = None,
             bwd_time_chunk: int | None = None,
             interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Whole-sequence stacked LSTM in ONE kernel dispatch.

    w: (L, P+H, 4H) stacked gate weights (stack_params); b: (L, 4H);
    x: (B, T, P) input zero-padded to width P (pad_input).
    Returns final (c, h), each (L, B, H).  Oracle: kernels/ref.lstm_seq.

    When ``block_b`` is None the ``(block_b, time_chunk)`` tiling comes
    from ``choose_batch_block`` — whole-T residency when it fits, streamed
    time chunks otherwise; an explicit ``time_chunk`` still pins the time
    layout (only the batch tile is chosen).  An explicit ``block_b`` pins
    the batch tile and ``time_chunk`` then selects the layout directly
    (None = whole-T resident; tc = double-buffered streaming), still ONE
    dispatch either way.

    ``bwd_block_b``/``bwd_time_chunk`` tile the TRAINING path (the
    trajectory-emitting forward + the reverse-sweep kernel, each ONE
    dispatch); defaults come from ``choose_batch_block(mode="bwd")``.  Pass
    ``bwd_block_b=ORACLE_BWD`` (0) to force the oracle-VJP fallback — which
    is also what happens automatically when even a ``(bm=1, tc=1)`` backward
    tiling cannot fit the VMEM budget.  Inference through ``lstm_seq`` never
    pays for residuals: the trajectory variant only runs under
    differentiation (custom_vjp fwd rule).
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B, T, xw = x.shape
    assert w.shape[1] == P + H and xw == P, (w.shape, x.shape)
    fwd_spec, bwd_spec = _resolve_specs(
        B, T, L, P, H, dtype_bytes=jnp.dtype(x.dtype).itemsize,
        w_dtype_bytes=jnp.dtype(w.dtype).itemsize, quantized=False,
        block_b=block_b, time_chunk=time_chunk, bwd_block_b=bwd_block_b,
        bwd_time_chunk=bwd_time_chunk)
    return _lstm_seq(w, b, x, fwd_spec, bwd_spec, interpret)
