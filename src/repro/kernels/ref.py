"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function is the mathematical specification its kernel is tested against
(tests/test_kernels_*.py sweep shapes/dtypes and assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Fused LSTM cell (kernels/lstm_cell.py)
# ---------------------------------------------------------------------------
def lstm_cell(w: jax.Array, b: jax.Array, x: jax.Array, c: jax.Array,
              h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """w: (D+H, 4H) gate order (i,f,g,o); x: (B,D); c,h: (B,H)."""
    xh = jnp.concatenate([x, h], axis=-1)
    gates = (xh.astype(jnp.float32) @ w.astype(jnp.float32)
             + b.astype(jnp.float32))
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c32 = c.astype(jnp.float32)
    c_new = jax.nn.sigmoid(f) * c32 + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return c_new.astype(c.dtype), h_new.astype(h.dtype)


# ---------------------------------------------------------------------------
# Sequence-resident stacked LSTM (kernels/lstm_seq.py)
# ---------------------------------------------------------------------------
def lstm_seq(w: jax.Array, b: jax.Array, x: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the whole-sequence stacked-LSTM kernel.

    w: (L, P+H, 4H) stacked gate weights (gate order i,f,g,o), where
    P >= H is the padded per-layer input width (see lstm_seq.stack_params);
    b: (L, 4H); x: (B, T, P) input already zero-padded to width P.
    Returns final (c, h), each (L, B, H) — h[-1] feeds the classifier head.
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B = x.shape[0]
    f32 = jnp.float32
    c0 = jnp.zeros((L, B, H), f32)
    h0 = jnp.zeros((L, B, H), f32)

    def step(carry, x_t):
        c, h = carry
        inp = x_t.astype(f32)                       # (B, P)
        cs, hs = [], []
        for l in range(L):
            # per-layer step IS the fused-cell oracle on the stacked
            # (P+H, 4H) weights: concat([inp, h]) @ w[l]
            c_new, h_new = lstm_cell(w[l], b[l], inp, c[l], h[l])
            cs.append(c_new)
            hs.append(h_new)
            inp = jnp.pad(h_new, ((0, 0), (0, P - H))) if P > H else h_new
        return (jnp.stack(cs), jnp.stack(hs)), None

    (c, h), _ = jax.lax.scan(step, (c0, h0), jnp.swapaxes(x, 0, 1))
    return c.astype(x.dtype), h.astype(x.dtype)


def lstm_seq_traj(w: jax.Array, b: jax.Array, x: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Trajectory-emitting oracle — the residual contract of the fused
    training path (lstm_seq._seq_traj_kernel / lstm_seq_bwd).

    Same math as ``lstm_seq``, but additionally returns the POST-step state
    trajectories ``(c_traj, h_traj)``, each (T, L, B, H) float32 — the f32
    values actually carried through the recurrence, NOT cast to x.dtype,
    because the backward kernel recomputes gates from them and the
    recompute must be bit-identical to the forward.
    Returns (c, h, c_traj, h_traj) with (c, h) exactly ``lstm_seq``'s.

    The contract is LAYOUT-INVARIANT: the time-chunked kernels (which
    stream the trajectories through VMEM in (tc, L, B, H) windows instead
    of holding T resident) emit and consume exactly these arrays — chunking
    changes data movement, never the residual values, so this single oracle
    specifies every (block_b, time_chunk) configuration.
    """
    L, H = w.shape[0], w.shape[-1] // 4
    P = w.shape[1] - H
    B = x.shape[0]
    f32 = jnp.float32
    c0 = jnp.zeros((L, B, H), f32)
    h0 = jnp.zeros((L, B, H), f32)

    def step(carry, x_t):
        c, h = carry
        inp = x_t.astype(f32)
        cs, hs = [], []
        for l in range(L):
            c_new, h_new = lstm_cell(w[l], b[l], inp, c[l], h[l])
            cs.append(c_new)
            hs.append(h_new)
            inp = jnp.pad(h_new, ((0, 0), (0, P - H))) if P > H else h_new
        new = (jnp.stack(cs), jnp.stack(hs))
        return new, new

    (c, h), (ct, ht) = jax.lax.scan(step, (c0, h0), jnp.swapaxes(x, 0, 1))
    return c.astype(x.dtype), h.astype(x.dtype), ct, ht


# ---------------------------------------------------------------------------
# Int8 weight quantization (kernels/lstm_seq.py `fused_seq_q8` plan)
#
# Contract (the "scale contract" in ROADMAP §Quantization): PER-OUTPUT-CHANNEL
# symmetric int8 — one f32 scale per (layer, gate column), no zero point.
# scale[l, j] = max_l_abs(w[l, :, j]) / 127, wq in [-127, 127], and the
# dequantized weight is wq.astype(f32) * scale.  Biases stay f32.  The fused
# kernels never materialise the dequantized stack: they dot against the int8
# block cast to f32 and fold the per-channel scale into the gate
# pre-activations ((x @ wq) * s == x @ (wq * s) exactly in reals, within fp
# rounding on hardware) — so kernel-vs-oracle equivalence is an ERROR BAND,
# not bit-exactness (tests/test_plan_equivalence.py documents both bands).
# ---------------------------------------------------------------------------
def quantize_q8(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-output-channel symmetric int8 quantization of a stacked weight
    block.  w: (L, P+H, 4H) -> (wq int8 same shape, scales f32 (L, 4H))."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=1)                 # (L, 4H)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.round(w32 / scales[:, None, :])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scales


def dequantize_q8(wq: jax.Array, scales: jax.Array) -> jax.Array:
    """Inverse map: (L, P+H, 4H) int8 x (L, 4H) f32 scales -> f32 weights."""
    return wq.astype(jnp.float32) * scales[:, None, :]


def quantize_dequantize_ste(w: jax.Array) -> jax.Array:
    """Straight-through quantize-dequantize: forward value is the dequantized
    int8 weight, gradient is the identity (d wdq / d w = 1).  This is the
    differentiation contract of the fused q8 training path — gradients are
    taken through the DEQUANTIZED weights the forward actually used, then
    passed straight through to the f32 master weights."""
    wdq = dequantize_q8(*quantize_q8(w))
    return w.astype(jnp.float32) + jax.lax.stop_gradient(
        wdq - w.astype(jnp.float32))


def lstm_seq_q8(wq: jax.Array, scales: jax.Array, b: jax.Array, x: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """Dequantize-then-run oracle for the quantized sequence kernel: the
    mathematical spec the fused q8 kernels are tested against (within the fp
    rounding band of the folded per-channel scaling)."""
    return lstm_seq(dequantize_q8(wq, scales), b, x)


def lstm_seq_q8_traj(wq: jax.Array, scales: jax.Array, b: jax.Array,
                     x: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Trajectory-emitting oracle of the q8 training path (residual contract
    of the quantized reverse sweep — same layout as ``lstm_seq_traj``)."""
    return lstm_seq_traj(dequantize_q8(wq, scales), b, x)


# ---------------------------------------------------------------------------
# RWKV6 chunked wkv scan (kernels/wkv6.py)
# ---------------------------------------------------------------------------
def wkv6_chunk(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
               u: jax.Array, state: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """One chunk of the RWKV6 recurrence for one (batch, head).

    r,k,logw: (C, dk); v: (C, dv); u: (dk,); state: (dk, dv).
      S_t = diag(exp(logw_t)) S_{t-1} + k_t^T v_t
      out_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    Stable within-chunk parallel form using only non-positive exponents.
    """
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    logw, u, state = logw.astype(f32), u.astype(f32), state.astype(f32)
    C = r.shape[0]
    L = jnp.cumsum(logw, axis=0)               # inclusive: L_i = sum_{j<=i}
    L_prev = L - logw                          # exclusive: L_{i-1}
    # carry term: r_i diag(exp(L_prev_i)) S
    out = (r * jnp.exp(L_prev)) @ state        # (C, dv)
    # intra-chunk term, j < i:  A[i,j,c] = exp(L_prev[i,c] - L[j,c])  (<= 0)
    diff = L_prev[:, None, :] - L[None, :, :]  # (C, C, dk)
    mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])
    # mask the exponent (j >= i entries are positive — exp would overflow
    # under strong decay and NaN the einsum VJP), not the scores
    diff = jnp.where(mask[:, :, None], diff, -jnp.inf)
    scores = jnp.einsum("ic,jc,ijc->ij", r, k, jnp.exp(diff))
    out = out + scores @ v
    # bonus (diagonal) term
    out = out + jnp.einsum("ic,c,ic->i", r, u, k)[:, None] * v
    # state update: S' = diag(exp(L_last)) S + sum_j diag(exp(L_last - L_j)) k_j^T v_j
    L_last = L[-1]
    decay_j = jnp.exp(L_last[None, :] - L)     # (C, dk), exponents <= 0
    state_new = jnp.exp(L_last)[:, None] * state + (k * decay_j).T @ v
    return out, state_new


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
         u: jax.Array, state: jax.Array, chunk: int
         ) -> tuple[jax.Array, jax.Array]:
    """Full-sequence oracle: scan wkv6_chunk over T/chunk chunks.

    r,k,logw: (T, dk); v: (T, dv); state: (dk, dv).  T % chunk == 0.
    """
    T = r.shape[0]
    n = T // chunk

    def step(s, xs):
        rc, kc, vc, wc = xs
        out, s = wkv6_chunk(rc, kc, vc, wc, u, s)
        return s, out

    xs = (r.reshape(n, chunk, -1), k.reshape(n, chunk, -1),
          v.reshape(n, chunk, -1), logw.reshape(n, chunk, -1))
    state, outs = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return outs.reshape(T, -1), state


def wkv6_stepwise(r, k, v, logw, u, state):
    """Per-timestep reference recurrence (the 'fine-grained' plan)."""
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    logw, u, state = logw.astype(f32), u.astype(f32), state.astype(f32)

    def step(s, xs):
        r_t, k_t, v_t, w_t = xs
        kv = jnp.outer(k_t, v_t)
        out = r_t @ (s + u[:, None] * kv)
        s = jnp.exp(w_t)[:, None] * s + kv
        return s, out

    state, outs = jax.lax.scan(step, state, (r, k, v, logw))
    return outs, state


# ---------------------------------------------------------------------------
# Blocked causal prefill attention (kernels/flash_prefill.py)
# ---------------------------------------------------------------------------
def prefill_attn(q: jax.Array, k: jax.Array, v: jax.Array,
                 window: int = 0, scale: float | None = None) -> jax.Array:
    """Naive causal attention oracle.  q: (B,S,Hq,dh); k,v: (B,S,Hkv,dh)."""
    B, S, Hq, dh = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    scale = dh ** -0.5 if scale is None else scale
    kr = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    vr = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr) * scale
    pos = jnp.arange(S)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Single-token flash-decode attention (kernels/decode_attn.py)
# ---------------------------------------------------------------------------
def decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                length: jax.Array | int, scale: float | None = None
                ) -> jax.Array:
    """q: (B, Hq, dk); caches: (B, S, Hkv, dk); length: valid cache length.

    GQA: query head h reads kv head h // (Hq // Hkv).  Returns (B, Hq, dk).
    """
    B, S, Hkv, dk = k_cache.shape
    Hq = q.shape[1]
    scale = scale if scale is not None else dk ** -0.5
    group = Hq // Hkv
    kc = jnp.repeat(k_cache.astype(jnp.float32), group, axis=2)  # (B,S,Hq,dk)
    vc = jnp.repeat(v_cache.astype(jnp.float32), group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kc) * scale
    valid = jnp.arange(S)[None, None, :] < jnp.asarray(length).reshape(-1, 1, 1)
    scores = jnp.where(valid, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, vc)
    return out.astype(q.dtype)
