"""Mamba (S6) fused selective-scan Pallas kernel — forward AND backward.

The third recurrence family on the MobiRNN substrate.  Unlike RWKV6, the
selective scan admits NO matmul-form chunking: the decay exp(dt ⊙ A) is
per-(channel, state) and data-dependent, so the (C, C) intra-chunk kernel
trick would blow up per channel x state (models/mamba.py, DESIGN.md).  The
coarse work unit here is therefore a STEPWISE chunk: one grid step advances
``chunk`` timesteps of a ``block_b`` batch tile with the faithful per-step
recurrence (a ``lax.scan`` inside the kernel body), and the f32
(block_b, d_inner, d_state) state lives in VMEM scratch carried across the
sequential time-chunk grid dimension — the paper's preallocated-state-reuse
rule, same as lstm_seq's (c, h) carries and wkv6's (dk, dv) state.  What
the fusion buys is MobiRNN's §3.1 dispatch economics: ONE ``pallas_call``
for any T instead of the XLA scan's per-step op stream, with chunking
changing I/O granularity ONLY — the per-step math is identical at every
(block_b, chunk), so results match the ``lax.scan`` oracle at plain f32
tolerances.

Tiling rides the shared ``core/tiling`` substrate: ``working_set_bytes``
is a WorkingSet term table (with the fwd/bwd mode split — the backward
holds the linearised scan residuals, the dominant bwd-only term) and
``choose_blocks`` runs the family-generic coarseness-ordered
``(block_b, chunk)`` joint search, whole-T residency first (``chunk=T`` —
one grid step per tile) before halving chunks, then batch tiles.

Autodiff mirrors kernels/wkv6.py: a ``jax.custom_vjp`` whose forward (under
differentiation) runs a trajectory-emitting variant writing the
CHUNK-INCOMING states ``h_traj (B, nt, di, ds)`` — the residual — and whose
backward is ONE reverse-order dispatch: the grid walks chunks backward via
reversed index maps, ``jax.vjp`` of the pure chunk scan re-linearises each
chunk from its stored incoming state, the state cotangent ``dh`` carries in
VMEM scratch, and ``da`` accumulates in scratch across ALL grid steps
(batch tiles included — the lstm_seq_bwd dw idiom) and is emitted once at
the last step.  ``value_and_grad`` is exactly 2 Pallas dispatches at any T.
``bwd=ORACLE_BWD`` differentiates the ``lax.scan`` reference instead —
the fallback when ``choose_blocks(mode="bwd")`` finds nothing.

Non-dividing shapes zero-pad at the END of either axis: padded steps have
dt = x = b = c = 0, which is the IDENTITY on the state (decay exp(0) = 1,
zero injection) and yields zero output rows the wrapper slices off; padded
batch rows are fully zero and independent, so the shared f32 state scratch
never leaks across rows.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import factorization, tiling

F32 = jnp.float32

#: ``bwd=`` sentinel: differentiate the lax.scan reference instead of
#: running the fused reverse sweep (the fallback past the bwd budget).
ORACLE_BWD = 0
#: ``bwd=`` default: ONE reverse-order Pallas dispatch for the whole sweep.
FUSED_BWD = 1


# ---------------------------------------------------------------------------
# VMEM budget — the (block_b, chunk) decision on the shared substrate.
# ---------------------------------------------------------------------------
class MambaBlocks(NamedTuple):
    """The fused scan's tiling decision: batch tile x time chunk.

    ``chunk`` here changes I/O granularity only (the recurrence is
    per-step either way) — larger chunks mean fewer grid steps and larger
    streamed tiles; ``chunk == seq_len`` is the whole-T-resident layout,
    one grid step per batch tile.

    Presents the family-generic ``core/tiling.TilePlan`` interface:
    ``batch_tile`` is this family's ``block_b``, ``time_chunk`` its
    ``chunk`` (whole-T residency is spelled ``chunk == seq_len`` here,
    never None)."""
    block_b: int
    chunk: int

    @property
    def batch_tile(self) -> int:
        return self.block_b

    @property
    def time_chunk(self) -> int:
        return self.chunk


def working_set_bytes(seq_len: int, d_inner: int, d_state: int,
                      block_b: int, chunk: int, dtype_bytes: int = 4,
                      mode: str = "fwd") -> int:
    """VMEM working set of one (block_b, chunk) grid step, per phase.

    ``mode="fwd"``: the pipelined x/dt/b/c input tiles and y output tile
    (x STREAM_SLOTS — pallas double-buffers revisited blocks), A, the
    h0/h_out blocks, and the f32 state scratch.

    ``mode="bwd"`` sizes the reverse-sweep dispatch, which strictly
    dominates the trajectory-emitting forward: on top of the forward set it
    holds the stored chunk-incoming state tile, the dy cotangent tile, the
    mirrored (dx, ddt, db, dc) output tiles, the dh scratch + dh0/dh_fin
    blocks, the da accumulator + output, and the linearised scan residuals
    (~3 state-sized tensors PER STEP of the chunk — the dominant bwd term,
    which is what pushes the chunk DOWN in training where the forward
    would happily take chunk = T)."""
    ws = tiling.WorkingSet(mode)
    C = max(1, min(chunk, seq_len))
    bm = max(1, block_b)
    in_tiles = (bm * C * d_inner * dtype_bytes        # x
                + bm * C * d_inner * 4                # dt (f32)
                + 2 * bm * C * d_state * 4)           # b, c (f32)
    out_tile = bm * C * d_inner * dtype_bytes
    state = bm * d_inner * d_state * 4
    ws.add("in_tiles", tiling.STREAM_SLOTS * in_tiles)
    ws.add("out_tile", tiling.STREAM_SLOTS * out_tile)
    ws.add("a", d_inner * d_state * 4)
    ws.add("state_io", 2 * state)                     # h0 in + h_out out
    ws.add("state_scratch", state)
    ws.add("htraj_tile", tiling.STREAM_SLOTS * state, bwd_only=True)
    ws.add("dy_tile", tiling.STREAM_SLOTS * out_tile, bwd_only=True)
    ws.add("grad_tiles", in_tiles, bwd_only=True)     # dx/ddt/db/dc
    ws.add("dh", 3 * state, bwd_only=True)            # scratch + dh0/dhf
    ws.add("da", 2 * d_inner * d_state * 4, bwd_only=True)
    ws.add("linearised_scan", 3 * C * state, bwd_only=True)
    return ws.total()


def choose_blocks(batch: int, seq_len: int, d_inner: int, d_state: int, *,
                  dtype_bytes: int = 4, vmem_budget: int | None = None,
                  mode: str = "fwd") -> MambaBlocks | None:
    """Pick the (block_b, chunk), or None when not viable — the shared
    ``core/tiling.joint_search`` in MobiRNN coarseness order: whole-T
    residency (``chunk = T``, one grid step per batch tile) at the full
    batch first, streamed chunks from T//2 down to 1 second, smaller batch
    tiles last.  Returns None only when even (1, 1) does not fit — the
    state blocks themselves blow VMEM; callers then route to the XLA scan
    (fwd) or the oracle VJP (bwd)."""
    budget = factorization.DEFAULT_VMEM_BUDGET if vmem_budget is None \
        else vmem_budget

    def fits(bm: int, tc: int | None) -> bool:
        c = seq_len if tc is None else tc
        return working_set_bytes(seq_len, d_inner, d_state, bm, c,
                                 dtype_bytes, mode=mode) <= budget

    found = tiling.joint_search(batch, seq_len, fits)
    if found is None:
        return None
    bm, tc = found
    return MambaBlocks(bm, seq_len if tc is None else tc)


# ---------------------------------------------------------------------------
# Shared chunk math — the single source of truth for fwd, traj, and bwd.
# ---------------------------------------------------------------------------
def _chunk_math(x, dt, b, c, a, h):
    """``chunk`` steps of the selective scan in f32, batched over the tile.
    x, dt: (bm, C, di); b, c: (bm, C, ds); a: (di, ds); h: (bm, di, ds).
    Returns (y (bm, C, di), h_new (bm, di, ds)).  The step body is the
    models/mamba._scan recurrence VERBATIM — chunking changes where the
    loop lives (inside one grid step), not the math."""

    def step(h, xs):
        x_t, dt_t, b_t, c_t = xs                     # (bm,di),(bm,di),(bm,ds)x2
        decay = jnp.exp(dt_t[..., None] * a)         # (bm,di,ds)
        dbx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = decay * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.swapaxes(x, 0, 1), jnp.swapaxes(dt, 0, 1),
          jnp.swapaxes(b, 0, 1), jnp.swapaxes(c, 0, 1))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.swapaxes(ys, 0, 1), h


def mamba_scan_ref(x, dt, b, c, a, h0):
    """Pure ``lax.scan`` reference over the whole sequence — the oracle
    plan (and the dtype contract: y in x.dtype, final state f32)."""
    ys, h = _chunk_math(x.astype(F32), dt.astype(F32), b.astype(F32),
                        c.astype(F32), a.astype(F32), h0.astype(F32))
    return ys.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------
def _load(refs):
    return tuple(ref[...].astype(F32) for ref in refs)


def _fwd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_out_ref,
              htraj_ref, state):
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    x, dt, b, c, a = _load((x_ref, dt_ref, b_ref, c_ref, a_ref))

    @pl.when(t == 0)
    def _init():
        state[...] = h0_ref[...].astype(F32)

    h_in = state[...]
    if htraj_ref is not None:
        htraj_ref[:, 0] = h_in                # incoming state of chunk t
    ys, h_new = _chunk_math(x, dt, b, c, a, h_in)
    state[...] = h_new
    y_ref[...] = ys.astype(y_ref.dtype)

    @pl.when(t == nt - 1)
    def _final():
        h_out_ref[...] = h_new.astype(h_out_ref.dtype)


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_out_ref,
            state):
    _fwd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_out_ref,
              None, state)


def _traj_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref,
                 h_out_ref, htraj_ref, state):
    """Trajectory-emitting forward: same math and dispatch count as
    ``_kernel``, plus the CHUNK-INCOMING states written to ``h_traj`` —
    the residual the reverse sweep re-linearises each chunk from."""
    _fwd_body(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, h_out_ref,
              htraj_ref, state)


def _bwd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, htraj_ref, dy_ref,
                dhf_ref, dx_ref, ddt_ref, db_ref, dc_ref, da_ref, dh0_ref,
                dh_scr, da_scr):
    """Reverse-time sweep over chunks — ONE dispatch for the whole
    backward.  Grid step t processes chunk nt-1-t (reversed index maps);
    ``dh`` carries in scratch per batch tile (seeded from the final-state
    cotangent at reverse step 0), ``da`` accumulates in scratch across ALL
    grid steps — batch tiles included — and is emitted once at the very
    last step (the lstm_seq_bwd dw-accumulator idiom); ``dh0`` is emitted
    per tile at the last reverse step."""
    ib = pl.program_id(0)
    t = pl.program_id(1)
    nb = pl.num_programs(0)
    nt = pl.num_programs(1)
    x, dt, b, c, a = _load((x_ref, dt_ref, b_ref, c_ref, a_ref))
    dy = dy_ref[...].astype(F32)
    h_in = htraj_ref[:, 0]                    # chunk-incoming state (f32)

    @pl.when(jnp.logical_and(ib == 0, t == 0))
    def _zero_da():
        da_scr[...] = jnp.zeros_like(da_scr)

    @pl.when(t == 0)
    def _seed_dh():
        dh_scr[...] = dhf_ref[...].astype(F32)

    _, chunk_vjp = jax.vjp(_chunk_math, x, dt, b, c, a, h_in)
    dx, ddt, db, dc, da, dh = chunk_vjp((dy, dh_scr[...]))
    dh_scr[...] = dh
    da_scr[...] = da_scr[...] + da
    dx_ref[...] = dx.astype(dx_ref.dtype)
    ddt_ref[...] = ddt.astype(ddt_ref.dtype)
    db_ref[...] = db.astype(db_ref.dtype)
    dc_ref[...] = dc.astype(dc_ref.dtype)

    @pl.when(t == nt - 1)                     # reverse-last = chunk 0
    def _emit_dh0():
        dh0_ref[...] = dh_scr[...].astype(dh0_ref.dtype)

    @pl.when(jnp.logical_and(ib == nb - 1, t == nt - 1))
    def _emit_da():
        da_ref[...] = da_scr[...].astype(da_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (T % chunk == 0, B % block_b == 0 — the entry pads)
# ---------------------------------------------------------------------------
def _fwd_call(x, dt, b, c, a, h0, chunk, block_b, interpret, traj: bool):
    B, T, di = x.shape
    ds = b.shape[-1]
    assert T % chunk == 0 and B % block_b == 0, (T, chunk, B, block_b)
    nt = T // chunk
    bm = block_b
    in_specs = [
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, t, 0)),
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, t, 0)),
        pl.BlockSpec((di, ds), lambda i, t: (0, 0)),
        pl.BlockSpec((bm, di, ds), lambda i, t: (i, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, t, 0)),
        pl.BlockSpec((bm, di, ds), lambda i, t: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, T, di), x.dtype),
        jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
    ]
    kernel = _kernel
    if traj:
        kernel = _traj_kernel
        out_specs.append(pl.BlockSpec((bm, 1, di, ds),
                                      lambda i, t: (i, t, 0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, nt, di, ds), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(B // bm, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, di, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, h0)


def _bwd_call(x, dt, b, c, a, h_traj, dy, dh_fin, h0_dtype, chunk, block_b,
              interpret):
    B, T, di = x.shape
    ds = b.shape[-1]
    nt = T // chunk
    bm = block_b
    rev = nt - 1                              # reversed chunk index map

    in_specs = [
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((di, ds), lambda i, t: (0, 0)),
        pl.BlockSpec((bm, 1, di, ds), lambda i, t: (i, rev - t, 0, 0)),
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, di, ds), lambda i, t: (i, 0, 0)),
    ]
    out_specs = [
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, di), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((bm, chunk, ds), lambda i, t: (i, rev - t, 0)),
        pl.BlockSpec((di, ds), lambda i, t: (0, 0)),
        pl.BlockSpec((bm, di, ds), lambda i, t: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(dt.shape, dt.dtype),
        jax.ShapeDtypeStruct(b.shape, b.dtype),
        jax.ShapeDtypeStruct(c.shape, c.dtype),
        jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.ShapeDtypeStruct((B, di, ds), h0_dtype),
    ]
    return pl.pallas_call(
        _bwd_kernel,
        grid=(B // bm, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, di, ds), jnp.float32),
                        pltpu.VMEM((di, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, h_traj, dy, dh_fin)


# ---------------------------------------------------------------------------
# custom VJP — 1 dispatch fwd, 2 dispatches per value_and_grad
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _mamba(x, dt, b, c, a, h0, chunk, block_b, bwd, interpret):
    y, h_out = _fwd_call(x, dt, b, c, a, h0, chunk, block_b, interpret,
                         traj=False)
    return y, h_out


def _mamba_fwd(x, dt, b, c, a, h0, chunk, block_b, bwd, interpret):
    if bwd == ORACLE_BWD:
        y, h_out = _fwd_call(x, dt, b, c, a, h0, chunk, block_b, interpret,
                             traj=False)
        return (y, h_out), (x, dt, b, c, a, h0, None)
    y, h_out, h_traj = _fwd_call(x, dt, b, c, a, h0, chunk, block_b,
                                 interpret, traj=True)
    return (y, h_out), (x, dt, b, c, a, h0, h_traj)


def _mamba_bwd(chunk, block_b, bwd, interpret, residuals, cots):
    x, dt, b, c, a, h0, h_traj = residuals
    dy, dh_fin = cots
    if bwd == ORACLE_BWD:
        _, oracle_vjp = jax.vjp(mamba_scan_ref, x, dt, b, c, a, h0)
        return oracle_vjp((dy, dh_fin))
    return _bwd_call(x, dt, b, c, a, h_traj, dy, dh_fin, h0.dtype, chunk,
                     block_b, interpret)


_mamba.defvjp(_mamba_fwd, _mamba_bwd)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_b", "bwd", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
               a: jax.Array, h0: jax.Array, *, chunk: int = 16,
               block_b: int | None = None, bwd: int = FUSED_BWD,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused selective scan over full sequences — ONE Pallas dispatch.

    x, dt: (B, T, di); b, c: (B, T, ds); a: (di, ds) (= -exp(a_log), f32);
    h0: (B, di, ds) f32.  Any T and B — non-dividing axes are zero-padded
    to the next chunk/block_b multiple (identity on the state: dt = 0 means
    decay 1 and zero injection) and the padded rows sliced off.  ``chunk``
    is clamped to T; ``block_b`` defaults to the whole batch (coarsest
    tile) and is clamped to B.  Returns (y (B, T, di) in x.dtype, final
    state (B, di, ds) f32).

    Differentiable: under ``jax.grad`` the forward becomes the
    trajectory-emitting kernel and the backward ONE reverse-sweep dispatch
    (``bwd=FUSED_BWD``, the default) — or the oracle VJP replay
    (``bwd=ORACLE_BWD``) when the caller's ``choose_blocks(mode="bwd")``
    found nothing viable.
    """
    B, T, di = x.shape
    chunk = max(1, min(chunk, T))
    block_b = B if block_b is None else max(1, min(block_b, B))
    from repro.obs import trace as trace_lib
    tracer = trace_lib.get_tracer()
    if tracer.enabled:
        tracer.event("plan/dispatch", family="mamba", plan="fused_scan",
                     chunk=chunk, block_b=block_b, bwd=bwd, batch=B,
                     seq_len=T)
    pad = (-T) % chunk
    padb = (-B) % block_b
    if pad or padb:
        def zpad(arr):
            return jnp.pad(arr, ((0, padb), (0, pad), (0, 0)))

        x, dt, b, c = zpad(x), zpad(dt), zpad(b), zpad(c)
        if padb:
            h0 = jnp.pad(h0, ((0, padb), (0, 0), (0, 0)))
    y, h_out = _mamba(x, dt, b, c, a, h0, chunk, block_b, bwd, interpret)
    if pad or padb:
        y = y[:B, :T]
        h_out = h_out[:B]
    return y, h_out
