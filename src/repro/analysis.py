"""Analytic model accounting + roofline-term derivation from compiled HLO.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 16 GiB HBM at
819 GB/s, ~50 GB/s per ICI link (values from the assignment brief).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)


# ---------------------------------------------------------------------------
# Kernel-dispatch accounting (MobiRNN §3.1: dispatch overhead is the enemy)
# ---------------------------------------------------------------------------
def _sub_jaxprs(value):
    """Yield every (Closed)Jaxpr nested in an eqn param value."""
    if hasattr(value, "eqns") or hasattr(value, "jaxpr"):
        yield value
    elif isinstance(value, (tuple, list)):
        for item in value:
            yield from _sub_jaxprs(item)


def count_kernel_dispatches(jaxpr) -> int:
    """Count ``pallas_call`` executions implied by a traced computation,
    multiplying through ``scan`` trip counts (a kernel inside a scanned body
    dispatches once per trip even though the jaxpr lists it once).

    This is the quantity MobiRNN §3.1 says dominates on constrained
    accelerators: the per-cell LSTM plan traces to T*L dispatches, the
    sequence-resident plan (kernels/lstm_seq.py) to exactly 1 — O(1) in T.
    ``cond`` branches count as their max; ``while`` bodies (trip count not
    static) count once, making the result a lower bound there.

    Accepts the return of ``jax.make_jaxpr(fn)(*args)``.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            total += 1
            continue
        subs = [j for v in eqn.params.values() for j in _sub_jaxprs(v)]
        if not subs:
            continue
        counts = [count_kernel_dispatches(j) for j in subs]
        if name == "scan":
            total += eqn.params["length"] * sum(counts)
        elif name == "cond":
            total += max(counts)
        else:                      # pjit / custom_vjp / while / remat ...
            total += sum(counts)
    return total


def count_train_dispatches(loss_fn, *args) -> int:
    """Kernel dispatches of ONE training step: the jaxpr of
    ``jax.value_and_grad(loss_fn)`` with the custom-VJP forward AND backward
    inlined by partial evaluation, counted by ``count_kernel_dispatches``.

    This is the training-story analogue of the forward dispatch rows: the
    per-cell plan's VJP unrolls to O(T*L) cell-backward dispatches, while
    the fused-seq plan's reverse-sweep kernel keeps the whole
    ``value_and_grad`` at exactly 2 — one trajectory-emitting forward + one
    BPTT sweep — O(1) in T (asserted by tests/test_plan_equivalence.py and
    tracked by benchmarks/run.py fig2 rows).
    """
    import jax

    return count_kernel_dispatches(
        jax.make_jaxpr(jax.value_and_grad(loss_fn))(*args))


def count_pallas_grid_steps(jaxpr) -> int:
    """Total Pallas GRID steps implied by a traced computation — the
    family-aware complement to ``count_kernel_dispatches``.

    Dispatch counts alone can't distinguish the chunked-scan plans' O(T/C)
    sequential work from an O(T) one: both are ONE ``pallas_call``.  Each
    pallas_call here contributes ``prod(grid)`` (e.g. the wkv6 kernel's
    ``(BH, ceil(T/C))`` grid counts BH * ceil(T/C) steps), so halving the
    chunk size doubles the number while the dispatch count stays 1 — the
    quantity the rwkv dispatch-regression rows pin down.  scan/cond/while
    recursion matches ``count_kernel_dispatches``; a pallas_call's own body
    jaxpr is NOT recursed into (its kernel runs once per grid step by
    definition).
    """
    import math

    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "pallas_call":
            total += math.prod(eqn.params["grid_mapping"].grid)
            continue
        subs = [j for v in eqn.params.values() for j in _sub_jaxprs(v)]
        if not subs:
            continue
        counts = [count_pallas_grid_steps(j) for j in subs]
        if name == "scan":
            total += eqn.params["length"] * sum(counts)
        elif name == "cond":
            total += max(counts)
        else:                      # pjit / custom_vjp / while / remat ...
            total += sum(counts)
    return total


def lstm_seq_stream_costs(seq_len: int, n_layers: int, p_width: int,
                          hidden: int, batch: int, block_b: int,
                          time_chunk: int | None, dtype_bytes: int = 4,
                          w_dtype_bytes: int | None = None,
                          mode: str = "fwd",
                          quantized: bool = False) -> dict[str, float]:
    """Roofline terms for ONE fused-LSTM dispatch under the streamed layout.

    The time-chunked kernels (kernels/lstm_seq.py / lstm_seq_bwd.py) trade
    VMEM residency for HBM streaming: per batch tile, the input crosses
    HBM->VMEM once in ceil(T/tc) chunks (clamped tail windows re-read up to
    tc-1 rows), the training path streams the two f32 trajectories out
    (fwd) and back in with a one-row overlap per chunk (bwd), and dx
    streams out.  Weights cross once per batch tile; the recurrent state
    never crosses at all — that is the point of the kernel.

    Returns ``flops`` (MXU work: 2 gate matmuls per cell fwd, 6 in the
    reverse sweep — gate recompute + dw + input/carry grads),
    ``hbm_bytes`` (total streamed traffic of the dispatch),
    ``vmem_resident_bytes`` (kernels/lstm_seq.working_set_bytes for the
    same tiling — O(tc) when chunked, O(T) when not), and ``t_compute`` /
    ``t_memory`` seconds at this chip's peak (PEAK_FLOPS / HBM_BW) — the
    two-term roofline of the pipelined kernel: the double buffer hides
    min(t_compute, t_memory) of the pair.

    ``mode="fwd"`` sizes the inference forward; ``mode="bwd"`` sizes the
    reverse-sweep dispatch (its trajectory-emitting forward is strictly
    cheaper on both axes).

    ``quantized=True`` sizes the int8-weight plan (``fused_seq_q8``): the
    streamed weight stack is 1 byte/weight with the f32 scales + biases
    riding along (~4x less weight traffic per batch tile), and the bwd
    dw/db write-out is f32 (straight-through master-weight gradients).
    """
    from repro.kernels import lstm_seq as seq_lib

    w_count = n_layers * (p_width + hidden) * 4 * hidden
    b_count = n_layers * 4 * hidden
    if quantized:
        wb = 1 if w_dtype_bytes is None else w_dtype_bytes
        weight_bytes = w_count * wb + b_count * 4 * 2   # + f32 bias + scales
        dw_bytes = (w_count + b_count) * 4              # f32 master grads
    else:
        wb = dtype_bytes if w_dtype_bytes is None else w_dtype_bytes
        weight_bytes = (w_count + b_count) * wb
        dw_bytes = weight_bytes
    n_tiles = math.ceil(batch / block_b)
    tc = seq_len if time_chunk is None else min(time_chunk, seq_len)
    nc = math.ceil(seq_len / tc)
    # streamed rows per batch tile: clamped tail windows re-read rows
    x_rows = nc * tc
    traj_rows = nc * (tc + 1 if nc > 1 else tc)
    x_bytes = x_rows * block_b * p_width * dtype_bytes
    traj_bytes = 2 * traj_rows * n_layers * block_b * hidden * 4
    state_out = 2 * n_layers * block_b * hidden * dtype_bytes

    matmul = 2 * block_b * (p_width + hidden) * 4 * hidden  # FLOPs/cell
    if mode == "fwd":
        per_tile_bytes = weight_bytes + x_bytes + state_out
        per_tile_flops = seq_len * n_layers * matmul
    else:
        # reverse sweep: x + both trajectories in, dx out, dw/db out once
        per_tile_bytes = (weight_bytes + x_bytes + traj_bytes
                          + x_bytes                      # dx mirrors x
                          + 2 * state_out)               # (dc, dh) cots in
        per_tile_flops = seq_len * n_layers * 3 * matmul
    hbm_bytes = n_tiles * per_tile_bytes
    if mode == "bwd":
        hbm_bytes += dw_bytes                            # dw/db written once
    flops = n_tiles * per_tile_flops
    resident = seq_lib.working_set_bytes(
        seq_len, n_layers, p_width, hidden, block_b, dtype_bytes,
        w_dtype_bytes, mode=mode, time_chunk=time_chunk,
        quantized=quantized)
    return {
        "flops": float(flops),
        "hbm_bytes": float(hbm_bytes),
        "vmem_resident_bytes": float(resident),
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": hbm_bytes / HBM_BW,
    }


def wkv6_stream_costs(seq_len: int, n_bh: int, dk: int, dv: int,
                      chunk: int, dtype_bytes: int = 4,
                      mode: str = "fwd", *,
                      bh_tile: int = 1) -> dict[str, float]:
    """Roofline terms for ONE chunked-scan WKV6 dispatch — the rwkv6
    analogue of ``lstm_seq_stream_costs``, priced from the kernels/wkv6
    streamed grid: per (bh-tile, chunk) step the four (bh_tile, C, dk/dv)
    input windows cross HBM->VMEM by explicit double-buffered DMA and the
    output tile streams back, while the (bh_tile, dk, dv) recurrent state
    stays in VMEM scratch for the whole time sweep — that residency is
    the point of the kernel.  Only the two in-flight window slots are
    resident; the traffic side prices every window at its FULL padded
    extent (``tiling.streamed_axis_rows`` / ``tiling.pad_tiles``): a
    non-dividing T or BH moves its identity zero-padding too, so the
    model stays honest about tail re-reads.

    FLOPs per chunk per batch-head row are the three MXU matmuls of
    ``_chunk_math`` (carry term, intra-chunk scores, score application)
    plus the state update: ``2*C*C*dk + 2*C*C*dv + 4*C*dk*dv``, counted
    over the padded grid (padded rows compute too).  ``mode="bwd"`` sizes
    the reverse-sweep dispatch: the linearised chunk recompute roughly
    triples compute, and the stored per-chunk state trajectory plus the
    mirrored cotangent windows stream on top of the forward traffic.

    Returns the same keys as ``lstm_seq_stream_costs`` (``flops``,
    ``hbm_bytes``, ``vmem_resident_bytes``, ``t_compute``, ``t_memory``)
    so obs/profile.py's model-vs-measured report can join any family.
    """
    from repro.core import tiling
    from repro.kernels import wkv6 as wkv6_lib

    tiling.check_mode(mode)
    C = max(1, min(chunk, seq_len))
    bt = max(1, min(bh_tile, n_bh))
    nc = tiling.ceil_chunks(seq_len, C)
    rows = tiling.pad_tiles(n_bh, bt)        # padded batch-head extent
    t_rows = tiling.streamed_axis_rows(seq_len, C)       # nc * C
    per_chunk_flops = 2 * C * C * dk + 2 * C * C * dv + 4 * C * dk * dv
    windows_in = rows * t_rows * (3 * dk + dv) * dtype_bytes  # r,k,logw,v
    out_tiles = rows * t_rows * dv * dtype_bytes
    state_io = rows * (2 * dk * dv * 4 + dk * 4)         # s0 + s_out + u
    flops = rows * nc * per_chunk_flops
    hbm_bytes = windows_in + out_tiles + state_io
    if mode == "bwd":
        flops *= 3                      # linearised recompute + cot flow
        # stored per-chunk state trajectory windows in, dout windows in,
        # dr/dk/dv/dlogw windows out, du/ds0 out once per row
        hbm_bytes += (rows * nc * dk * dv * 4
                      + out_tiles + windows_in
                      + rows * (dk * 4 + dk * dv * 4))
    resident = wkv6_lib.working_set_bytes(seq_len, dk, dv, C, dtype_bytes,
                                          mode=mode, bh_tile=bt)
    return {
        "flops": float(flops),
        "hbm_bytes": float(hbm_bytes),
        "vmem_resident_bytes": float(resident),
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": hbm_bytes / HBM_BW,
    }


def mamba_scan_stream_costs(seq_len: int, batch: int, d_inner: int,
                            d_state: int, block_b: int, chunk: int,
                            dtype_bytes: int = 4,
                            mode: str = "fwd") -> dict[str, float]:
    """Roofline terms for ONE fused selective-scan dispatch — the mamba
    analogue of ``wkv6_stream_costs``, priced from the kernels/mamba_scan
    (batch-tile, time-chunk) grid: per step the (bm, C, d_inner/d_state)
    input tiles for x, dt, B and C stream HBM->VMEM and the output tile
    streams back, while the (bm, d_inner, d_state) f32 state stays in
    VMEM scratch across the time sweep.  Padded extents are priced in
    full (``tiling.pad_tiles`` / ``tiling.streamed_axis_rows``) — the
    identity zero-pad (dt=0) moves across HBM like real rows.

    Per step per row the recurrence costs ~``8 * d_inner * d_state``
    FLOPs (decay exp + multiply, outer-product injection, contraction
    with C).  ``mode="bwd"`` sizes the reverse-sweep dispatch: the
    linearised per-chunk recompute roughly triples compute, and the
    stored state trajectory plus mirrored cotangent tiles stream on top.

    Returns the same keys as the other ``*_stream_costs`` so
    obs/profile.py's model-vs-measured report can join any family.
    """
    from repro.core import tiling
    from repro.kernels import mamba_scan as ms_lib

    tiling.check_mode(mode)
    C = max(1, min(chunk, seq_len))
    bm = max(1, min(block_b, batch))
    nc = tiling.ceil_chunks(seq_len, C)
    rows = tiling.pad_tiles(batch, bm)       # padded batch extent
    t_rows = tiling.streamed_axis_rows(seq_len, C)       # nc * C
    per_step_flops = 8 * d_inner * d_state
    # x in dtype; dt f32; b, c f32
    tiles_in = rows * t_rows * (d_inner * dtype_bytes + d_inner * 4
                                + 2 * d_state * 4)
    out_tiles = rows * t_rows * d_inner * dtype_bytes
    state_io = rows * 2 * d_inner * d_state * 4          # h0 + h_out
    a_bytes = d_inner * d_state * 4                      # A crosses once
    flops = rows * t_rows * per_step_flops
    hbm_bytes = tiles_in + out_tiles + state_io + a_bytes
    if mode == "bwd":
        flops *= 3                      # linearised recompute + cot flow
        # stored per-chunk state trajectory in, dy in, dx/ddt/db/dc out,
        # dA + dh0 out once
        hbm_bytes += (rows * nc * d_inner * d_state * 4
                      + out_tiles + tiles_in
                      + a_bytes + rows * d_inner * d_state * 4)
    resident = ms_lib.working_set_bytes(seq_len, d_inner, d_state, bm, C,
                                        dtype_bytes, mode=mode)
    return {
        "flops": float(flops),
        "hbm_bytes": float(hbm_bytes),
        "vmem_resident_bytes": float(resident),
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": hbm_bytes / HBM_BW,
    }


# ---------------------------------------------------------------------------
# Analytic parameter counts
# ---------------------------------------------------------------------------
def _attn_params(cfg: ModelConfig) -> int:
    d, hq, hkv, dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    n = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
    if cfg.qkv_bias:
        n += hq * dh + 2 * hkv * dh
    return n


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _moe_params(cfg: ModelConfig, active: bool) -> int:
    moe = cfg.moe
    n_exp = moe.top_k if active else moe.n_experts
    return (cfg.d_model * moe.n_experts            # router (always dense)
            + n_exp * _mlp_params(cfg, moe.d_ff))


def _rwkv_params(cfg: ModelConfig) -> int:
    d, r = cfg.d_model, cfg.ssm.lora_rank
    tmix = (5 * d * d                  # r,k,v,g,o projections
            + d * 5 * 32 + 5 * 32 * d  # ddlerp lora
            + d * r + r * d            # decay lora
            + 7 * d)                   # mu vectors, w0, u, groupnorm
    cmix = 2 * d * cfg.d_ff + d * d + 2 * d
    return tmix + cmix


def _mamba_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ds = cfg.ssm.d_state
    dr = math.ceil(d / 16)
    return (d * 2 * di + cfg.ssm.d_conv * di + di
            + di * (dr + 2 * ds) + dr * di + di
            + di * ds + di + di * d)


def param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) analytic parameter counts."""
    total = active = 0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            t = a = _attn_params(cfg)
        elif cfg.ssm.kind == "rwkv6":
            t = a = _rwkv_params(cfg)
        else:
            t = a = _mamba_params(cfg)
        if cfg.ssm is None or cfg.ssm.kind != "rwkv6":
            if cfg.layer_is_moe(i):
                t += _moe_params(cfg, active=False)
                a += _moe_params(cfg, active=True)
            else:
                t += _mlp_params(cfg, cfg.d_ff)
                a += _mlp_params(cfg, cfg.d_ff)
        total += t + 4 * cfg.d_model            # norms
        active += a + 4 * cfg.d_model
    emb = cfg.vocab * cfg.d_model * (cfg.n_codebooks or 1)
    head = 0 if cfg.tie_embeddings else emb
    extra = 0
    if cfg.n_vis_tokens:  # vlm projector (2-layer mlp with biases)
        extra = (cfg.vis_dim * cfg.d_model + cfg.d_model
                 + cfg.d_model * cfg.d_model + cfg.d_model)
    total += emb + head + extra
    active += emb + head + extra
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6·N_active·tokens for training, 2·N_active·tokens for
    inference (embedding lookups excluded from N per convention)."""
    _, active = param_counts(cfg)
    emb = cfg.vocab * cfg.d_model * (cfg.n_codebooks or 1)
    n = active - emb
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch     # decode: per generated token


# ---------------------------------------------------------------------------
# Analytic per-step cost model (itemized; the napkin-math backbone of §Perf)
#
# XLA's compiled.cost_analysis() counts while-loop bodies ONCE, so with
# scan-over-layers (and scanned attention/ssm blocks) it reports ~one group's
# flops.  We therefore derive the compute and memory roofline terms from this
# analytic model and use the HLO numbers as a per-group cross-check
# (EXPERIMENTS.md §Dry-run records both).
# ---------------------------------------------------------------------------
def _layer_flops(cfg: ModelConfig, i: int, T: float, s_att: float,
                 decode: bool) -> float:
    """Forward flops of layer i for T tokens; s_att = attended positions."""
    d, ff = cfg.d_model, cfg.d_ff
    fl = 0.0
    kind = cfg.layer_kind(i)
    if kind == "attn":
        hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        fl += 2 * T * d * (hq + 2 * hkv) * dh      # qkv proj
        fl += 2 * T * hq * dh * d                  # out proj
        fl += 4 * T * s_att * hq * dh              # QK^T + AV
    elif cfg.ssm.kind == "rwkv6":
        dh = cfg.ssm.head_dim
        C = cfg.ssm.chunk if not decode else 1
        fl += 2 * T * d * d * 5                    # r,k,v,g,o projections
        fl += 2 * T * d * (cfg.ssm.lora_rank * 2 + 5 * 32 * 2)  # loras
        fl += 4 * T * d * (C + dh)                 # wkv chunk math
        fl += 2 * T * (2 * d * ff + d * d)         # channel-mix
        return fl
    else:  # mamba
        di = cfg.ssm.expand * d
        ds = cfg.ssm.d_state
        dr = math.ceil(d / 16)
        fl += 2 * T * d * 2 * di + 2 * T * di * (dr + 2 * ds)
        fl += 2 * T * dr * di + 2 * cfg.ssm.d_conv * T * di
        fl += 8 * T * di * ds                      # selective scan step math
        fl += 2 * T * di * d
    # mlp / moe half
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    if cfg.layer_is_moe(i):
        moe = cfg.moe
        cf = 1.0 if decode else moe.capacity_factor
        fl += 2 * T * d * moe.n_experts            # router
        fl += 2 * mult * T * moe.top_k * cf * d * moe.d_ff
    else:
        fl += 2 * mult * T * d * ff
    return fl


def analytic_costs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, float]:
    """Total flops and HBM bytes of one global step (all chips combined)."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    T = float(B if decode else B * S)
    if decode:
        window = cfg.sliding_window or 0
        s_att = min(S, window) if window else S    # cache positions read
    else:
        # baseline chunked attention computes ALL kv blocks (masked), so the
        # attended length is S, not S/2 — this waste is itself a §Perf lever
        s_att = float(S)
    fwd = sum(_layer_flops(cfg, i, T, s_att, decode)
              for i in range(cfg.n_layers))
    fwd += 2 * T * cfg.d_model * cfg.vocab * (cfg.n_codebooks or 1)  # head
    # train: 1 fwd + 1 remat recompute + 2x bwd  = 4x forward flops
    flops = fwd * (4.0 if shape.kind == "train" else 1.0)

    # ---- bytes ----
    p_total, _ = param_counts(cfg)
    dt = 2 if cfg.dtype == "bfloat16" else 4
    p_bytes = p_total * dt
    act_unit = T * cfg.d_model * dt                # one activation tensor
    if shape.kind == "train":
        # params: read fwd + recompute + bwd, write once; adamw moments rw
        byts = p_bytes * 4 + p_total * (4 + 4) * 2 * 2
        byts += act_unit * 12 * cfg.n_layers       # activations r/w
        byts += T * cfg.vocab * 4 * 3              # logits fwd+bwd
    elif shape.kind == "prefill":
        byts = p_bytes + act_unit * 8 * cfg.n_layers
        byts += cache_bytes(cfg, B, S)             # cache write
        byts += B * cfg.vocab * 4
    else:
        byts = p_bytes                              # weights stream once
        byts += cache_bytes(cfg, B, S) * (1 + 1e-3)  # cache read (+tiny write)
        byts += act_unit * 8 * cfg.n_layers
        byts += B * cfg.vocab * 4
    return {"flops": flops, "bytes": float(byts), "fwd_flops": fwd}


def cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """Decode-state bytes for a batch of B requests at context S."""
    dt = 2 if cfg.dtype == "bfloat16" else 4
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            w = cfg.sliding_window or 0
            s_c = min(S, w) if w else S
            if cfg.kv_quant:   # int8 values + one f32 scale per (tok, head)
                total += 2 * B * s_c * cfg.n_kv_heads * (
                    cfg.resolved_head_dim * 1 + 4)
            else:
                total += (2 * B * s_c * cfg.n_kv_heads
                          * cfg.resolved_head_dim * dt)
        elif cfg.ssm.kind == "rwkv6":
            H, dh = cfg.d_model // cfg.ssm.head_dim, cfg.ssm.head_dim
            total += B * (H * dh * dh * 4 + 2 * cfg.d_model * dt)
        else:
            di = cfg.ssm.expand * cfg.d_model
            total += B * (di * cfg.ssm.d_state * 4
                          + (cfg.ssm.d_conv - 1) * di * dt)
    return total


# ---------------------------------------------------------------------------
# Collective-bytes extraction from post-SPMD HLO text
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z]+[0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*[a-z0-9]+\[[^\]]*\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"\(")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _first_shape_bytes(line: str) -> int:
    """Bytes of the result shape(s) on an HLO line (post-SPMD = per-device)."""
    total = 0
    for m in _SHAPE_RE.finditer(line.split("=")[0] + "="):
        pass
    # result type is everything before the op name: parse the lhs annotation
    lhs = line.split("=", 1)
    if len(lhs) < 2:
        return 0
    rhs = lhs[1]
    m = _SHAPE_RE.search(rhs)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str, kind: str) -> int:
    rhs = line.split("=", 1)[1]
    paren = rhs.index(kind)
    result_part = rhs[:paren]
    byts = 0
    for sm in _SHAPE_RE.finditer(result_part):
        n = 1
        for d in sm.group(2).split(","):
            if d:
                n *= int(d)
        byts += n * _DTYPE_BYTES.get(sm.group(1), 4)
    return byts


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"\bwhile\(.*\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def parse_hlo_computations(hlo_text: str):
    """Split post-SPMD HLO text into {computation: [instruction lines]} and
    return (computations, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for raw in hlo_text.splitlines():
        if raw and not raw[0].isspace() and "{" in raw:
            m = _COMP_HEADER_RE.match(raw)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry = current
                continue
        if raw.strip() == "}":
            current = None
        elif current is not None:
            comps[current].append(raw.strip())
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count of a scan-style while: the bound constant in the condition
    (lax.scan lowers to `compare(iter, constant(N)), direction=LT`)."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device collective result bytes by type, SCALED BY LOOP TRIP
    COUNTS (a collective inside a scanned-layer while body executes once per
    trip; XLA's flat text lists it once)."""
    comps, entry = parse_hlo_computations(hlo_text)
    if entry is None:
        return {}
    mult: dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    # propagate multiplicities in call order (HLO computations are listed
    # bottom-up; iterate to a fixpoint — call graphs are shallow)
    for _ in range(len(comps)):
        changed = False
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for name, lines in comps.items():
            m = mult[name]
            if not m:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    new[body] = new.get(body, 0.0) + m * trips
                    new[cond] = new.get(cond, 0.0) + m * (trips + 1)
                    continue
                for cm in _CALL_RE.finditer(line):
                    callee = cm.group(1)
                    if callee in comps:
                        new[callee] = new.get(callee, 0.0) + m
        if new != mult:
            mult = new
            changed = True
        if not changed:
            break

    out: dict[str, int] = {}
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if not m:
            continue
        for line in lines:
            cm = _COLLECTIVE_RE.search(line)
            if cm:
                kind = cm.group(1)
                out[kind] = out.get(kind, 0) + int(_result_bytes(line, kind)
                                                   * m)
    return out


# ring-cost multiplier: fraction of the result bytes that actually crosses a
# link per chip for each collective type on an N-way ring
def ici_seconds(coll: dict[str, int], n_shards: int = 16) -> float:
    f = (n_shards - 1) / max(n_shards, 1)
    mult = {"all-gather": f, "reduce-scatter": f, "all-reduce": 2 * f,
            "all-to-all": f / 2, "collective-permute": 1.0}
    return sum(mult.get(k, 1.0) * v for k, v in coll.items()) / ICI_BW


@dataclasses.dataclass
class Roofline:
    flops: float                 # total HLO flops (all devices)
    hbm_bytes: float             # total HLO bytes accessed (all devices)
    coll_bytes: dict[str, int]   # per-device collective result bytes
    n_chips: int
    model_flops: float

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return ici_seconds(self.coll_bytes)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "collective_bytes": self.coll_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
        }
