"""Step functions shared by training, serving, smoke tests and the dry-run.

All functions take PLAIN pytrees (post ``partitioning.split``); sharding is
applied by the callers via in_shardings/out_shardings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean cross-entropy; logits (..., V) fp32, targets (...) int."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_fn(params: Any, cfg: ModelConfig, batch: dict, *,
            remat: bool = True) -> tuple[jax.Array, dict]:
    logits, aux = transformer.forward(params, cfg, batch, remat=remat)
    toks = batch["tokens"]
    if cfg.n_codebooks:
        # logits (B,K,S,V): every codebook predicts its own next token
        loss = _xent(logits[:, :, :-1], toks[:, :, 1:])
    elif cfg.n_vis_tokens:
        # layout [vis | text]: position n_vis-1+i predicts text token i
        nv = cfg.n_vis_tokens
        loss = _xent(logits[:, nv - 1:-1], toks)
    else:
        loss = _xent(logits[:, :-1], toks[:, 1:])
    metrics = {"xent": loss}
    if cfg.moe is not None:
        n_moe = sum(cfg.layer_is_moe(i) for i in range(cfg.n_layers))
        lb = aux["moe_load_balance"] / max(n_moe, 1)
        zl = aux["moe_z_loss"] / max(n_moe, 1)
        loss = loss + cfg.moe.router_aux_weight * (lb + 0.1 * zl)
        metrics.update(moe_load_balance=lb, moe_z_loss=zl,
                       moe_drop_frac=aux["moe_drop_frac"] / max(n_moe, 1))
    metrics["loss"] = loss
    return loss, metrics


def train_step(optimizer, cfg: ModelConfig, params: Any, opt_state: dict,
               batch: dict) -> tuple[Any, dict, dict]:
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, cfg, batch)
    params, opt_state, opt_metrics = optimizer.update(grads, opt_state,
                                                      params)
    metrics.update(opt_metrics)
    return params, opt_state, metrics


def eval_step(cfg: ModelConfig, params: Any, batch: dict) -> dict:
    loss, metrics = loss_fn(params, cfg, batch, remat=False)
    return metrics


def prefill_step(cfg: ModelConfig, params: Any, cache: Any, batch: dict
                 ) -> tuple[jax.Array, Any]:
    return transformer.prefill(params, cfg, cache, batch)


def chunked_prefill_step(cfg: ModelConfig, params: Any, cache: Any,
                         batch: dict) -> tuple[jax.Array, Any]:
    """One fixed-shape admission-prefill chunk (transformer.prefill_chunk):
    ``batch['tokens']`` is a (B, L) prompt slice whose absolute start is
    the TRACED ``cache['pos']``, so ONE compiled executable per chunk
    length L serves every chunk of every prompt — the one-shape-per-
    ``(chunk_len,)`` contract chunked admission is built on."""
    return transformer.prefill_chunk(params, cfg, cache, batch)


def decode_step(cfg: ModelConfig, params: Any, cache: Any, batch: dict
                ) -> tuple[jax.Array, Any]:
    return transformer.decode_step(params, cfg, cache, batch)


def masked_decode_step(cfg: ModelConfig, params: Any, cache: Any,
                       batch: dict, step_fn: Any = None
                       ) -> tuple[jax.Array, Any]:
    """One fused decode tick across B slots honouring a per-slot active mask.

    ``batch['active']`` is a (B,) bool mask; ``cache['pos']`` must be the
    per-lane (B,) vector form.  Inactive lanes (free slots, finished
    requests) still ride through the fixed-shape computation — that is the
    point: ONE dispatch per tick regardless of occupancy — but their cache
    slices and position counters are reselected from the input cache, so a
    dead lane is semantically a no-op and its logits are garbage the caller
    must ignore.  ``step_fn`` defaults to ``decode_step``; alternate decode
    plans are wrapped the same way by the serving engine.
    """
    step = step_fn or decode_step
    active = batch["active"]
    logits, new_cache = step(cfg, params, cache,
                             {k: v for k, v in batch.items() if k != "active"})

    def sel(new, old):
        # cache slot leaves are (n_groups, B, ...): batch axis is 1
        m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
        return jnp.where(m, new, old)

    slots = jax.tree.map(sel, new_cache["slots"], cache["slots"])
    pos = jnp.where(active, new_cache["pos"], cache["pos"])
    return logits, {"pos": pos, "slots": slots}


def guarded_decode_step(cfg: ModelConfig, params: Any, cache: Any,
                        batch: dict, step_fn: Any = None
                        ) -> tuple[jax.Array, jax.Array, Any]:
    """``masked_decode_step`` plus the per-lane finite guard — the serving
    fault path's device half, folded into the SAME jit as the tick (one
    extra reduction, no extra dispatch, no shape change).

    ``batch['poison']`` is an optional (B,) bool fault-injection hook
    (serving/faults.FaultPlan): poisoned lanes' logits are overwritten with
    NaN INSIDE the jit, exercising exactly the guard a genuinely non-finite
    lane would trip.  Returns ``(logits, lane_ok, new_cache)`` where
    ``lane_ok`` is (B,) bool — False iff an ACTIVE lane produced non-finite
    logits this tick (inactive lanes carry garbage logits by design and
    never report faults).  With an all-False poison mask the logits are
    bit-identical to the unguarded tick: ``where`` with a false mask and
    the ``isfinite`` reduction change no values.
    """
    active = batch["active"]
    poison = batch.get("poison")
    logits, new_cache = masked_decode_step(
        cfg, params, cache,
        {k: v for k, v in batch.items() if k != "poison"}, step_fn=step_fn)
    if poison is not None:
        m = poison.reshape((-1,) + (1,) * (logits.ndim - 1))
        logits = jnp.where(m, jnp.asarray(jnp.nan, logits.dtype), logits)
    finite = jnp.all(jnp.isfinite(logits),
                     axis=tuple(range(1, logits.ndim)))
    return logits, finite | ~active, new_cache


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
