"""Architecture registry: the 10 assigned architectures + the paper's LSTM."""
from repro.configs.base import INPUT_SHAPES, ModelConfig, MoEConfig, SSMConfig, ShapeConfig
from repro.configs import (
    command_r_35b,
    internvl2_1b,
    jamba_1_5_large_398b,
    mobirnn_lstm,
    musicgen_large,
    olmoe_1b_7b,
    qwen2_0_5b,
    qwen3_moe_30b_a3b,
    rwkv6_3b,
    stablelm_12b,
    yi_9b,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        yi_9b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        qwen2_0_5b.CONFIG,
        command_r_35b.CONFIG,
        musicgen_large.CONFIG,
        internvl2_1b.CONFIG,
        stablelm_12b.CONFIG,
        olmoe_1b_7b.CONFIG,
        rwkv6_3b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
    ]
}

MOBIRNN_LSTM = mobirnn_lstm.CONFIG


def get_arch(name: str) -> ModelConfig:
    if name.endswith("-reduced"):
        return ARCHS[name[: -len("-reduced")]].reduced()
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


__all__ = [
    "ARCHS", "INPUT_SHAPES", "MOBIRNN_LSTM", "ModelConfig", "MoEConfig",
    "SSMConfig", "ShapeConfig", "get_arch", "get_shape",
]
