"""InternVL2-1B — InternViT-300M vision encoder + Qwen2-0.5B language decoder
[arXiv:2404.16821].

Backbone only (per assignment): the ViT is a stub — ``input_specs`` provides
precomputed patch embeddings (n_vis_tokens x vis_dim) which a learned 2-layer
projector maps into the decoder's embedding space and prepends to the text
token sequence.  The language decoder below is the Qwen2-0.5B configuration
with InternVL2's vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    mlp_act="swiglu",
    norm="rms",
    rope_theta=1_000_000.0,
    n_vis_tokens=256,         # 256 patch tokens per image tile
    vis_dim=1024,             # InternViT-300M hidden size
    source="arXiv:2404.16821",
)
