"""OLMoE-1B-7B — MoE decoder: 64 experts, top-8, every layer
[arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,            # MHA
    d_ff=1024,                # per-expert FFN hidden dim
    vocab=50304,
    head_dim=128,
    qkv_bias=False,
    mlp_act="swiglu",
    norm="rms",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024, every=1),
    source="arXiv:2409.02060",
)
