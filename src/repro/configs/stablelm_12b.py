"""StableLM-2-12B — dense GQA decoder [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    head_dim=160,
    qkv_bias=False,
    mlp_act="swiglu",
    norm="ln",                # StableLM-2 uses LayerNorm
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)
