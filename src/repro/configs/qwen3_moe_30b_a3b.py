"""Qwen3-30B-A3B — MoE decoder: 128 experts, top-8, every layer
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                 # per-expert FFN hidden dim
    vocab=151936,
    head_dim=128,             # decoupled from d_model (Qwen3 style)
    qkv_bias=False,
    mlp_act="swiglu",
    norm="rms",
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768, every=1),
    source="hf:Qwen/Qwen3-30B-A3B",
)
