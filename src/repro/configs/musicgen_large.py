"""MusicGen-large — decoder-only transformer over EnCodec audio tokens
[arXiv:2306.05284].

Backbone only (per assignment): the EnCodec tokenizer/codec is a stub; the
model consumes 4 parallel codebook token streams (delay pattern collapsed to
sum-of-codebook-embeddings) and predicts all 4 codebooks per step via 4 heads.
The original uses learned sinusoidal positions; we use RoPE (TPU-idiomatic,
noted in DESIGN.md) — the decoder structure (MHA kv=32, GELU FFN, LN) is kept.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,            # full multi-head attention
    d_ff=8192,
    vocab=2048,               # EnCodec codebook size
    head_dim=64,
    qkv_bias=False,
    mlp_act="gelu",
    norm="ln",
    rope_theta=10_000.0,
    n_codebooks=4,
    source="arXiv:2306.05284",
)
