"""Jamba-1.5-Large (398B) — hybrid Mamba+attention (1:7 interleave) with MoE
[arXiv:2403.19887].

Layer pattern (period 8): one attention layer per 8 (at period midpoint),
seven Mamba layers; MoE MLP on every second layer (16 experts, top-2).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    qkv_bias=False,
    mlp_act="swiglu",
    norm="rms",
    rope_theta=10_000.0,      # jamba attn layers are NoPE; rope kept, noted
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576, every=2),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2, chunk=64),
    attn_every=8,
    source="arXiv:2403.19887",
)
