"""Model and input-shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the dry-run,
smoke tests, training and serving drivers all consume the same config type.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden dim
    every: int = 1               # MoE MLP every Nth layer (1 = all layers)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "rwkv6"          # 'rwkv6' | 'mamba'
    d_state: int = 16            # mamba state size per channel
    d_conv: int = 4              # mamba conv width
    expand: int = 2              # mamba inner expansion
    head_dim: int = 64           # rwkv6 head size
    lora_rank: int = 64          # rwkv6 data-dependent decay LoRA rank
    chunk: int = 32              # chunked-scan block length (coarse factorization)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_act: str = "swiglu"      # swiglu | gelu
    norm: str = "rms"            # rms | ln
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: one attention layer every `attn_every` layers
    # (remaining layers in the period are SSM).  0 = all-attention.
    attn_every: int = 0
    # sliding-window attention (ring-buffer decode cache); 0 = full attention
    sliding_window: int = 0
    # vlm: number of vision-patch embeddings prepended to the text sequence
    n_vis_tokens: int = 0
    vis_dim: int = 0             # raw patch-embedding dim (projector input)
    # audio: number of EnCodec codebooks (parallel token streams)
    n_codebooks: int = 0
    # shard the sequence dim of activations over the 'model' mesh axis
    # (sequence parallelism; used by attention-free archs whose head count
    # cannot shard over the model axis — see DESIGN.md / §Perf C1)
    seq_shard: bool = False
    # int8 KV cache (per-token-per-head scales): halves decode cache
    # streaming, the dominant roofline term after §Perf B2
    kv_quant: bool = False
    dtype: str = "bfloat16"
    source: str = ""             # provenance citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    @property
    def attention_free(self) -> bool:
        return self.n_heads == 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i of the stack."""
        if self.attention_free:
            return "ssm"
        if self.attn_every and self.ssm is not None:
            # jamba-style: one attention layer per period, at period midpoint
            return "attn" if i % self.attn_every == self.attn_every // 2 else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every == self.moe.every - 1
                                         if self.moe.every > 1 else True)

    @property
    def period(self) -> int:
        """Layer-pattern period for scan-over-layers grouping."""
        p = 1
        if self.attn_every and self.ssm is not None:
            p = self.attn_every
        if self.moe is not None and self.moe.every > 1:
            import math
            p = math.lcm(p, self.moe.every)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return p

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 periods of layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        period = 1
        if self.attn_every and self.ssm is not None:
            period = self.attn_every
        n_layers = max(2, period)
        if self.moe is not None and self.moe.every > 1:
            import math
            n_layers = max(n_layers, math.lcm(period, self.moe.every))
        heads = 0 if self.attention_free else min(self.n_heads, 4)
        kvh = 0 if self.attention_free else max(1, min(self.n_kv_heads,
                                                       heads, 2))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff=min(self.moe.d_ff, 128))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, head_dim=min(self.ssm.head_dim, 32),
                lora_rank=16, chunk=8, d_state=min(self.ssm.d_state, 8))
        return dataclasses.replace(
            self, name=self.name + "-reduced", n_layers=n_layers,
            d_model=d_model, n_heads=heads, n_kv_heads=kvh,
            d_ff=min(self.d_ff, 384), vocab=min(self.vocab, 512),
            head_dim=(64 if not self.attention_free else 0),
            moe=moe, ssm=ssm,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            n_vis_tokens=min(self.n_vis_tokens, 8),
            vis_dim=min(self.vis_dim, 64) if self.vis_dim else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
