"""Command-R 35B — dense GQA decoder, no biases, LayerNorm
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    head_dim=128,
    qkv_bias=False,
    mlp_act="swiglu",
    norm="ln",                # Cohere uses (bias-free) LayerNorm
    rope_theta=8_000_000.0,
    tie_embeddings=True,      # command-r ties the LM head
    source="hf:CohereForAI/c4ai-command-r-v01",
)
