"""The paper's own model: stacked LSTM for human activity recognition.

MobiRNN §4.1: 2 layers x 32 hidden units (default), input = 128 timesteps of
9-dim smartphone sensor readings, 6 activity classes (UCI HAR dataset shape).
Complexity sweeps in Figs 5/6 vary hidden in {32..256} and layers in {1..3}.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LSTMConfig:
    name: str = "mobirnn-har"
    n_layers: int = 2
    hidden: int = 32
    input_dim: int = 9           # sensor channels
    seq_len: int = 128           # readings per window
    n_classes: int = 6           # activity labels
    dtype: str = "float32"

    def with_complexity(self, hidden: int, n_layers: int) -> "LSTMConfig":
        return dataclasses.replace(
            self, hidden=hidden, n_layers=n_layers,
            name=f"mobirnn-har-h{hidden}l{n_layers}")


CONFIG = LSTMConfig()
