"""RWKV6 (Finch) 3B — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

This is the architecture where the paper's (MobiRNN's) technique applies in
full: the wkv state scan is the LSTM-cell analogue; the chunked scan is the
coarse work-unit factorization; per-layer (state, shift) buffers live in the
preallocated state pool.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=0,                # attention-free
    n_kv_heads=0,
    d_ff=8960,                # channel-mix hidden dim (3.5x)
    vocab=65536,
    norm="ln",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, lora_rank=64, chunk=32),
    seq_shard=True,   # 40 heads can't shard over a 16-way model axis;
                      # sequence parallelism + affine-prefix wkv pipeline
    source="arXiv:2404.05892",
)
