"""Slot-resident continuous batching over preallocated recurrent state.

The wave engine (serving/engine.Engine) serves lockstep batches: every
request in a wave is padded to the longest prompt and the longest
``max_new_tokens``, so one long request stalls its lane-mates and finished
lanes ride along as dead weight.  This module turns the batch axis into B
independent **slots**:

* each slot owns a fixed lane (index ``i`` of the batch axis) of ONE
  preallocated cache buffer checked out from core/state.StatePool — lane
  state for RNN/SSM/attention families is fixed-shape, so no paged-KV
  machinery is needed;
* requests wait in a bounded ``RequestQueue`` (FIFO, backpressure by
  raising ``QueueFull``, per-request deadlines);
* admission prefills the new prompt through a B=1 scratch cache and
  left-packs it into the free lane with a donated scatter jit
  (``cache.at[:, i]``-style ``dynamic_update_slice``, no reallocation);
* every tick runs ONE fused masked decode step across all lanes
  (steps.masked_decode_step) — free/finished lanes are carried by a per-slot
  active mask and per-lane ``pos`` counters inside the batch dict;
* retirement zeroes JUST that lane in place (core/state.lane_zero under a
  donated jit) and the next queued request is admitted immediately.

Invariants (the MobiRNN rules at serving granularity):
  * fixed shapes — the decode tick has ONE shape for the life of the
    engine, whatever the occupancy;
  * no serving-path allocation — pool buffers are built once
    (``StatePool.stats.buffers_built == capacity`` forever); admission,
    decode and retirement all run through donated jits;
  * step-granular admission/retirement — a lane never waits for its
    neighbours (RTMobile's real-time admission argument, PAPERS.md).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import state as state_lib


class QueueFull(RuntimeError):
    """Bounded-queue backpressure: the caller must retry or shed load."""


class FinishReason:
    """The CLOSED set of terminal request states.  Every Result carries
    exactly one of these (validated in ``Result.__post_init__``) — the
    fault-tolerance contract is that a request always terminates with a
    DEFINITE reason, never a stringly-typed ad-hoc label:

      * ``LENGTH``            — produced its full ``max_new_tokens`` budget;
      * ``DEADLINE``          — ``deadline_s`` passed (queued: zero tokens;
                                resident: whatever it produced so far);
      * ``ERROR``             — lane quarantined (non-finite decode output)
                                or prefill failure, with no retry budget;
      * ``RETRIES_EXHAUSTED`` — quarantined/failed more times than the
                                engine's ``retry_budget`` allowed;
      * ``SHED``              — dropped from the queue by the degradation
                                ladder: its deadline was provably unmeetable
                                under the observed tick latency.
    """
    LENGTH = "length"
    DEADLINE = "deadline"
    ERROR = "error"
    RETRIES_EXHAUSTED = "retries_exhausted"
    SHED = "shed"


FINISH_REASONS = frozenset({
    FinishReason.LENGTH, FinishReason.DEADLINE, FinishReason.ERROR,
    FinishReason.RETRIES_EXHAUSTED, FinishReason.SHED})


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32 (or (K,S) for audio)
    max_new_tokens: int = 16
    # absolute deadline on the engine clock (time.monotonic by default);
    # None = no deadline.  Expired requests are retired with
    # finish_reason='deadline' — from the queue without running, from a
    # slot with whatever tokens they produced so far.
    deadline_s: float | None = None


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray               # (m,) or (K, m); m may be 0 on expiry
    prefill_s: float
    decode_s: float
    plan_decisions: list[str]
    finish_reason: str = FinishReason.LENGTH   # one of FINISH_REASONS
    #: admission -> first sampled token available on host, seconds.
    #: 0.0 for requests that never reached a lane (queue expiry,
    #: zero-token budgets) — mirrors prefill_s there.
    ttft_s: float = 0.0

    def __post_init__(self) -> None:
        if self.finish_reason not in FINISH_REASONS:
            raise ValueError(
                f"finish_reason {self.finish_reason!r} outside the closed "
                f"set {sorted(FINISH_REASONS)}")


@dataclasses.dataclass
class TokenEvent:
    """One streamed token (or a terminal marker) surfaced per tick."""
    uid: int
    token: np.ndarray | None         # () or (K,) int32; None on tokenless end
    index: int                       # position within the request's output
    done: bool
    finish_reason: str | None = None


def chunk_schedule(prompt_len: int, chunk_len: int) -> list[int]:
    """Fixed-shape segment decomposition of one prompt: ``prompt_len //
    chunk_len`` full chunks, then the remainder in DESCENDING powers of
    two (its binary decomposition).

    The point is the compiled-shape bound: every segment length is either
    ``chunk_len`` or a power of two below it, so however ragged the
    prompt mix, the chunked-prefill jit compiles at most
    ``1 + ceil(log2(chunk_len))`` executables — unlike whole-prompt
    admission, which compiles one per DISTINCT prompt length.  Bigger
    segments come first, so the tail segments (the cheap ones) are what
    lands between the final decode ticks before admission."""
    if prompt_len < 0 or chunk_len < 1:
        raise ValueError(f"chunk_schedule({prompt_len}, {chunk_len})")
    full, r = divmod(prompt_len, chunk_len)
    segs = [chunk_len] * full
    for b in reversed(range(r.bit_length())):
        if (r >> b) & 1:
            segs.append(1 << b)
    return segs


@dataclasses.dataclass
class PrefillLane:
    """State machine for one partially-prefilled admission (the tentpole
    of chunked prefill): holds the request, its B=1 scratch cache
    (checked out from the engine's scratch StatePool; returned at
    admission, abort, or failure — ``buffers_built`` stays at capacity
    through every path), and the remaining fixed-shape segment schedule.

    Lifecycle: FILLING (schedule non-empty) -> DONE (``done``: last
    chunk's sampled token is ready and the lane admits into a free slot)
    | ABORTED (deadline passed mid-prefill: partial state is discarded by
    the pool's donated zeroing reset) | FAILED (a chunk attempt raised —
    injected or real; retry restarts from chunk 0 with a zeroed scratch,
    so the retried prefill is bit-identical to an unfaulted one)."""
    request: Request
    cache: Any                      # B=1 scratch, owned until release
    schedule: list[int]             # remaining segment lengths
    prompt: np.ndarray = None       # int32 view of request.prompt
    filled: int = 0                 # prompt tokens already prefilled
    chunks_done: int = 0
    t_start: float = 0.0            # perf_counter at lane start (TTFT)
    prefill_s: float = 0.0          # accumulated chunk dispatch time
    last_tok: Any = None            # device token from the latest chunk

    @property
    def done(self) -> bool:
        return not self.schedule


class RequestQueue:
    """Bounded FIFO admission queue with deadline expiry."""

    def __init__(self, capacity: int, clock: Callable[[], float] = None):
        assert capacity >= 1
        self.capacity = capacity
        self.clock = clock or time.monotonic
        self._q: collections.deque[Request] = collections.deque()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def full(self) -> bool:
        return len(self._q) >= self.capacity

    def submit(self, req: Request, now: float | None = None) -> bool:
        """Queue one request.  Returns True when queued; False when the
        request is dead on arrival — its ``deadline_s`` has ALREADY passed,
        so queueing it would be dead work that only surfaces at the next
        tick's expiry sweep (the caller publishes the immediate
        ``finish_reason='deadline'`` Result).  Raises QueueFull
        (backpressure) when the bounded capacity is reached."""
        if req.deadline_s is not None:
            now = self.clock() if now is None else now
            if req.deadline_s <= now:
                return False
        if self.full:
            raise QueueFull(
                f"RequestQueue full (capacity={self.capacity}); "
                "slot-resident serving bounds queued work — retry later")
        self._q.append(req)
        return True

    def expire(self, now: float | None = None) -> list[Request]:
        """Remove and return every queued request whose deadline passed.

        One pass, partitioned by identity — ``deque.remove`` would compare
        dataclasses whose ndarray prompts make ``==`` ambiguous."""
        now = self.clock() if now is None else now
        expired: list[Request] = []
        keep: collections.deque[Request] = collections.deque()
        for r in self._q:
            if r.deadline_s is not None and r.deadline_s <= now:
                expired.append(r)
            else:
                keep.append(r)
        self._q = keep
        return expired

    def shed(self, predicate: Callable[[Request], bool]) -> list[Request]:
        """Remove and return every queued request ``predicate`` marks as
        sheddable (the degradation ladder's provably-unmeetable sweep).
        Same identity-partitioned single pass as ``expire``."""
        dropped: list[Request] = []
        keep: collections.deque[Request] = collections.deque()
        for r in self._q:
            (dropped if predicate(r) else keep).append(r)
        self._q = keep
        return dropped

    def pop(self) -> Request | None:
        return self._q.popleft() if self._q else None


@dataclasses.dataclass
class Slot:
    """Host-side bookkeeping for one lane of the resident cache."""
    index: int
    request: Request | None = None
    remaining: int = 0               # decode tokens still owed
    tokens: list = dataclasses.field(default_factory=list)
    prefill_s: float = 0.0
    admitted_t: float = 0.0
    ttft_s: float = 0.0              # admit -> first token, host-visible
    last_token_t: float = 0.0        # perf_counter of the latest token (TBT)
    plan_decisions: list = dataclasses.field(default_factory=list)

    @property
    def occupied(self) -> bool:
        return self.request is not None


class SlotManager:
    """B lanes of one pooled cache buffer + the donated lane-granular jits.

    The manager owns the device cache (``pos`` in its per-lane (B,) vector
    form) and the per-slot host records; the engine owns params, jits and
    the scheduler and drives ticks.
    """

    def __init__(self, cache: Any, n_slots: int, token_tail: tuple[int, ...],
                 clock: Callable[[], float] = None):
        self.cache = cache
        self.n_slots = n_slots
        self.clock = clock or time.monotonic
        self.slots = [Slot(i) for i in range(n_slots)]
        self._token_tail = token_tail
        # the tick inputs live ON DEVICE and are only touched by the
        # donated admit/reset jits (lane scatters) and the tick itself —
        # no per-tick host->device upload of tokens or mask
        self.tokens = jnp.zeros((n_slots,) + token_tail, jnp.int32)
        self.active = jnp.zeros((n_slots,), bool)

        def admit_fn(cache, tokens, active, lane, tok0, i):
            slots = state_lib.lane_write(cache["slots"], lane["slots"], i,
                                         axis=1)
            pos = cache["pos"].at[i].set(lane["pos"].astype(jnp.int32))
            return ({"pos": pos, "slots": slots},
                    tokens.at[i].set(tok0), active.at[i].set(True))

        def reset_fn(cache, tokens, active, i):
            slots = state_lib.lane_zero(cache["slots"], i, axis=1)
            pos = cache["pos"].at[i].set(0)
            return ({"pos": pos, "slots": slots},
                    tokens.at[i].set(0), active.at[i].set(False))

        self._admit = state_lib.donate(admit_fn, (0, 1, 2))
        self._reset = state_lib.donate(reset_fn, (0, 1, 2))

    # -- occupancy ------------------------------------------------------
    def free_indices(self) -> list[int]:
        return [s.index for s in self.slots if not s.occupied]

    @property
    def any_occupied(self) -> bool:
        return any(s.occupied for s in self.slots)

    def active_mask(self) -> np.ndarray:
        return np.array([s.occupied and s.remaining > 0
                         for s in self.slots], bool)

    def expired_indices(self, now: float | None = None) -> list[int]:
        now = self.clock() if now is None else now
        return [s.index for s in self.slots
                if s.occupied and s.request.deadline_s is not None
                and s.request.deadline_s <= now]

    # -- lane lifecycle -------------------------------------------------
    def admit(self, index: int, req: Request, lane_cache: Any,
              first_token: Any, prefill_s: float,
              ttft_s: float | None = None) -> Slot:
        """Left-pack a freshly prefilled request into a free lane.

        ``lane_cache`` is the B=1 scratch cache holding the prompt's state
        (scalar ``pos`` = prompt length); its single lane is scattered into
        lane ``index`` through the donated admit jit, together with the
        prompt's first sampled token (``first_token``, device array).
        ``ttft_s`` is the admit->first-token wall time the engine measured
        (the first token IS produced at admission); defaults to
        ``prefill_s`` for callers that do not separate the two."""
        s = self.slots[index]
        assert not s.occupied, index
        self.cache, self.tokens, self.active = self._admit(
            self.cache, self.tokens, self.active, lane_cache, first_token,
            jnp.asarray(index, jnp.int32))
        s.request = req
        s.tokens = [np.asarray(first_token, np.int32)]
        s.remaining = req.max_new_tokens - 1
        s.prefill_s = prefill_s
        s.admitted_t = time.perf_counter()
        s.ttft_s = prefill_s if ttft_s is None else ttft_s
        s.last_token_t = s.admitted_t
        s.plan_decisions = []
        return s

    def retire(self, index: int,
               finish_reason: str = FinishReason.LENGTH) -> Result:
        """Reset ONE lane in place and free the slot for the next request."""
        s = self.slots[index]
        assert s.occupied, index
        self.cache, self.tokens, self.active = self._reset(
            self.cache, self.tokens, self.active,
            jnp.asarray(index, jnp.int32))
        toks = (np.stack(s.tokens, axis=-1) if s.tokens
                else self.empty_tokens())
        res = Result(uid=s.request.uid, tokens=toks, prefill_s=s.prefill_s,
                     decode_s=time.perf_counter() - s.admitted_t,
                     plan_decisions=s.plan_decisions,
                     finish_reason=finish_reason, ttft_s=s.ttft_s)
        self.slots[index] = Slot(index)
        return res

    def empty_tokens(self) -> np.ndarray:
        """Zero-length token array of the right per-request shape."""
        return np.zeros(self._token_tail + (0,), np.int32)

    # -- tick interface -------------------------------------------------
    def tick_batch(self) -> dict:
        """The fixed-shape, device-resident batch for one fused masked
        decode step — nothing is uploaded per tick."""
        return {"tokens": self.tokens, "active": self.active}

    def set_sampled(self, sampled: Any) -> None:
        """Adopt one tick's sampled tokens (device array) as the next
        tick's inputs — garbage in inactive lanes is masked or overwritten
        at admission."""
        self.tokens = sampled

    def record(self, sampled: np.ndarray, plan: str) -> list[int]:
        """Fold one tick's greedy samples (host copy) into the active
        lanes; returns the indices that just produced their final token."""
        finished = []
        for s in self.slots:
            if not (s.occupied and s.remaining > 0):
                continue
            s.tokens.append(np.asarray(sampled[s.index], np.int32))
            s.remaining -= 1
            s.plan_decisions.append(plan)
            if s.remaining == 0:
                finished.append(s.index)
        return finished
