from repro.serving.engine import Engine, EngineConfig, SlotEngine
from repro.serving.faults import (FaultInjector, FaultPlan, InjectedFault,
                                  LanePoison, PrefillFault, QueueFlood,
                                  SlowTick)
from repro.serving.slots import (FINISH_REASONS, FinishReason, PrefillLane,
                                 QueueFull, Request, RequestQueue, Result,
                                 Slot, SlotManager, TokenEvent,
                                 chunk_schedule)

__all__ = ["Engine", "EngineConfig", "SlotEngine", "Request", "Result",
           "RequestQueue", "QueueFull", "Slot", "SlotManager", "TokenEvent",
           "PrefillLane", "chunk_schedule",
           "FinishReason", "FINISH_REASONS", "FaultPlan", "FaultInjector",
           "InjectedFault", "LanePoison", "PrefillFault", "SlowTick",
           "QueueFlood"]
