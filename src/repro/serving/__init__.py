from repro.serving.engine import Engine, SlotEngine
from repro.serving.faults import (FaultInjector, FaultPlan, InjectedFault,
                                  LanePoison, PrefillFault, QueueFlood,
                                  SlowTick)
from repro.serving.slots import (FINISH_REASONS, FinishReason, QueueFull,
                                 Request, RequestQueue, Result, Slot,
                                 SlotManager, TokenEvent)

__all__ = ["Engine", "SlotEngine", "Request", "Result", "RequestQueue",
           "QueueFull", "Slot", "SlotManager", "TokenEvent",
           "FinishReason", "FINISH_REASONS", "FaultPlan", "FaultInjector",
           "InjectedFault", "LanePoison", "PrefillFault", "SlowTick",
           "QueueFlood"]
