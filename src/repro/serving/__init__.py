from repro.serving.engine import Engine, Request, Result

__all__ = ["Engine", "Request", "Result"]
