from repro.serving.engine import Engine, SlotEngine
from repro.serving.slots import (QueueFull, Request, RequestQueue, Result,
                                 Slot, SlotManager, TokenEvent)

__all__ = ["Engine", "SlotEngine", "Request", "Result", "RequestQueue",
           "QueueFull", "Slot", "SlotManager", "TokenEvent"]
