"""Serving engines with MobiRNN-style runtime policies.

The paper mechanisms are first-class here:
  * preallocated state pools (core/state.StatePool) — decode caches are
    built once and reset in place through donated jits; no allocation on the
    serving path, pool exhaustion = explicit backpressure;
  * load-aware dispatch (core/scheduler.Scheduler) — multiple decode plans
    are registered and the predicted-fastest under current load runs each
    tick (paper Fig 7);
  * fixed-shape batching — the decode step has one shape for the life of
    the engine.

Two engines share that substrate:

``Engine`` — the coarse WAVE engine: requests are packed into lockstep
waves of ``batch_size``; every request pads to the longest prompt and the
longest ``max_new_tokens`` in its wave.  Short waves are padded with
zero-length dummy requests (an inactive lane, not a duplicated real
request).  Kept as the baseline the benchmarks compare against.

``SlotEngine`` — slot-resident CONTINUOUS batching (serving/slots.py): the
batch axis is B independent slots over one preallocated cache; requests are
admitted from a bounded queue into free slots at step granularity, decode
runs one fused masked step across all lanes per tick, and retirement resets
just that lane and immediately admits the next request.  Tokens stream out
per tick (``stream``/``on_token``) instead of arriving all at once.  This
is the engine the ROADMAP's heavy-traffic north star builds on.

Both engines are modality-generic: they serve any registry.Model whose
config family is text-like (dense/moe/ssm/hybrid/vlm/audio all decode
token ids).
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.core.state import StatePool, make_buffer
from repro.obs import trace as trace_lib
from repro.obs.metrics import Metrics
from repro.models.registry import Model
from repro.partitioning import split
from repro.serving import faults as faults_lib
from repro.serving.slots import (FinishReason, PrefillLane, QueueFull,
                                 Request, RequestQueue, Result, SlotManager,
                                 TokenEvent, chunk_schedule)
from repro import steps as steps_lib


@dataclasses.dataclass
class EngineConfig:
    """The consolidated construction surface for both engines — every
    queue/retry/ladder/fault/chunk knob in one dataclass instead of
    sprawled across ``Engine``/``SlotEngine`` kwargs.  Engines take
    ``config=EngineConfig(...)``; the old per-engine kwargs remain as
    deprecated aliases (DeprecationWarning) so downstream callers migrate
    at their own pace.  Unused knobs are simply ignored by the engine that
    does not implement them (``pool_capacity`` is a wave knob — the slot
    engine always runs ONE resident cache; ``queue_capacity``/retry/
    ladder/chunk knobs are slot knobs).

    Chunked prefill (``prefill_chunk_len``):
      * ``None`` (default) keeps whole-prompt admission — one B=1 prefill
        dispatch per request, one compiled executable per DISTINCT prompt
        length, and one long prompt stalls every resident lane's decode
        tick for its whole prefill;
      * an int enables chunk-interleaved admission: prompts prefill
        through up-to-``prefill_lanes`` PrefillLane state machines, at
        most ONE fixed-shape chunk between decode ticks, and admit into a
        slot only when fully prefilled.  Greedy outputs are token-
        identical to whole-prompt prefill — chunking changes scheduling,
        not math.
    """
    n_slots: int = 4
    max_seq: int = 128
    queue_capacity: int = 16
    pool_capacity: int = 2
    #: admission-prefill chunk length (None = whole-prompt admission)
    prefill_chunk_len: int | None = None
    #: concurrent partially-prefilled requests (chunked mode only)
    prefill_lanes: int = 2
    retry_budget: int = 0
    retry_backoff_s: float = 0.0
    tick_slo_s: float | None = None
    slo_breach_ticks: int = 3
    slo_recover_ticks: int = 8
    shed_margin: float = 1.0
    ladder: list[str] | None = None
    faults: faults_lib.FaultPlan | None = None

    @property
    def batch_size(self) -> int:
        """Wave-engine naming for the batch axis (== ``n_slots``)."""
        return self.n_slots


#: deprecated per-engine kwarg -> EngineConfig field
_WAVE_ALIASES = {"batch_size": "n_slots", "max_seq": "max_seq",
                 "pool_capacity": "pool_capacity"}
_SLOT_ALIASES = {k: k for k in (
    "n_slots", "max_seq", "queue_capacity", "faults", "retry_budget",
    "retry_backoff_s", "tick_slo_s", "slo_breach_ticks",
    "slo_recover_ticks", "shed_margin", "ladder")}


def _resolve_config(cls_name: str, config: EngineConfig | None,
                    legacy: dict, aliases: dict) -> EngineConfig:
    """Fold an engine's deprecated construction kwargs into EngineConfig.

    Exactly one spelling per call: legacy kwargs warn (DeprecationWarning
    pointing at the caller) and build a fresh config through the alias
    map; mixing them with an explicit ``config`` is ambiguous and raises."""
    if not legacy:
        return config if config is not None else EngineConfig()
    unknown = sorted(set(legacy) - set(aliases))
    if unknown:
        raise TypeError(
            f"{cls_name}: unexpected keyword argument(s) {unknown}")
    if config is not None:
        raise ValueError(
            f"{cls_name}: pass config=EngineConfig(...) OR the deprecated "
            f"kwargs {sorted(legacy)}, not both")
    warnings.warn(
        f"{cls_name}({', '.join(sorted(legacy))}) kwargs are deprecated; "
        "pass config=EngineConfig(...)", DeprecationWarning, stacklevel=3)
    return EngineConfig(**{aliases[k]: v for k, v in legacy.items()})


class _EngineBase:
    """Shared substrate: cache pool, prefill jit, decode-plan scheduler."""

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 max_seq: int, pool_capacity: int, sensor,
                 extra_plans: dict[str, Callable] | None, per_lane_pos: bool):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq

        cache_annot = jax.eval_shape(
            lambda: model.init_cache(batch_size, max_seq))
        cache_abs, _ = split(cache_annot)
        if per_lane_pos:
            # continuous batching: each lane decodes at its own position
            cache_abs = dict(cache_abs, pos=jax.ShapeDtypeStruct(
                (batch_size,), jnp.int32))
        self.pool = StatePool(cache_abs, capacity=pool_capacity)

        # shape-polymorphic: the same jit serves (B, S) wave prefills and
        # (1, S) per-slot admission prefills (one compile per shape)
        self._prefill = jax.jit(
            lambda p, c, b: steps_lib.prefill_step(self.cfg, p, c, b),
            donate_argnums=(1,))

        self.scheduler = Scheduler(sensor or SyntheticLoadSensor(0.0))
        for name, fn in self._decode_plans(extra_plans or {}).items():
            self.scheduler.register(
                Plan(name, jax.jit(fn, donate_argnums=(1,)), shared=True))

        # serving metrics are ALWAYS on: obs.metrics instruments are plain
        # host ints/deques, so they cannot violate the zero-allocation
        # serving invariant (tests assert buffers_built stays at capacity
        # with metrics enabled); tracing stays opt-in via obs.trace
        self.metrics = Metrics()

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        raise NotImplementedError

    def _prefill_batch(self, toks: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.n_vis_tokens:
            batch["vis_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_vis_tokens, self.cfg.vis_dim),
                jnp.dtype(self.cfg.dtype))
        return batch


# ---------------------------------------------------------------------------
# Wave engine (baseline)
# ---------------------------------------------------------------------------
class Engine(_EngineBase):
    """Lockstep wave engine — the coarse-batching baseline."""

    def __init__(self, model: Model, params: Any, *,
                 config: EngineConfig | None = None, sensor=None,
                 extra_plans: dict[str, Callable] | None = None, **legacy):
        config = _resolve_config("Engine", config, legacy, _WAVE_ALIASES)
        self.config = config
        super().__init__(model, params, batch_size=config.n_slots,
                         max_seq=config.max_seq,
                         pool_capacity=config.pool_capacity,
                         sensor=sensor, extra_plans=extra_plans,
                         per_lane_pos=False)

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        plans = {"decode/base":
                 lambda p, c, b: steps_lib.decode_step(self.cfg, p, c, b)}
        plans.update(extra)
        return plans

    # ------------------------------------------------------------------
    def _dummy_request(self) -> Request:
        """Zero-length, zero-token filler for ragged wave tails — an
        inactive lane, NOT a duplicate of a real request."""
        shape = ((self.cfg.n_codebooks, 0) if self.cfg.n_codebooks
                 else (0,))
        return Request(uid=-1, prompt=np.zeros(shape, np.int32),
                       max_new_tokens=0)

    def _pad_prompts(self, reqs: list[Request]) -> tuple[np.ndarray, int]:
        lens = [r.prompt.shape[-1] for r in reqs]
        s = max(lens)
        shape = ((self.batch_size, self.cfg.n_codebooks, s)
                 if self.cfg.n_codebooks else (self.batch_size, s))
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, ..., s - r.prompt.shape[-1]:] = r.prompt  # left-pad
        return toks, s

    def serve(self, requests: list[Request]) -> list[Result]:
        """Serve all requests in fixed-shape waves of `batch_size`."""
        results: list[Result] = []
        for i in range(0, len(requests), self.batch_size):
            wave = requests[i:i + self.batch_size]
            pad = self.batch_size - len(wave)
            wave_padded = wave + [self._dummy_request()] * pad
            results.extend(self._serve_wave(wave_padded)[: len(wave)])
        return results

    def _serve_wave(self, reqs: list[Request]) -> list[Result]:
        cache = self.pool.checkout()
        toks, _ = self._pad_prompts(reqs)
        batch = self._prefill_batch(toks)

        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(
            self._prefill(self.params, cache, batch))
        t_prefill = time.perf_counter() - t0

        max_new = max(r.max_new_tokens for r in reqs)
        outs = []
        decisions = []
        tracer = trace_lib.get_tracer()
        wave_span = (tracer.span("serve/wave", n_reqs=len(reqs),
                                 max_new=max_new, prefill_s=t_prefill)
                     if tracer.enabled else trace_lib.NULL_SPAN)
        # prefill logits keep a singleton seq axis before the vocab dim
        tok = steps_lib.greedy_sample(logits)[..., 0]
        t0 = time.perf_counter()
        with wave_span:
            for _ in range(max_new):
                outs.append(np.asarray(tok))
                d = self.scheduler.choose()
                decisions.append(d.plan)
                plan = self.scheduler.plans[d.plan]
                t1 = time.perf_counter()
                logits, cache = jax.block_until_ready(
                    plan.fn(self.params, cache, {"tokens": tok}))
                plan.observe(time.perf_counter() - t1, d.load)
                tok = steps_lib.greedy_sample(logits)
            t_decode = time.perf_counter() - t0
            wave_span.set(decode_s=t_decode)
        self.pool.give_back(cache)
        self.metrics.counter("serving/waves").inc()
        self.metrics.histogram("serving/wave_prefill_s").observe(t_prefill)
        self.metrics.histogram("serving/wave_decode_s").observe(t_decode)

        # (B, [K,] max_new); toks[..., :0] covers an all-zero-budget wave
        gen = (np.stack(outs, axis=-1) if outs else toks[..., :0])
        return [Result(r.uid, gen[j, ..., :r.max_new_tokens], t_prefill,
                       t_decode, decisions)
                for j, r in enumerate(reqs)]


# ---------------------------------------------------------------------------
# Slot engine (continuous batching)
# ---------------------------------------------------------------------------
class SlotEngine(_EngineBase):
    """Slot-resident continuous batching (see serving/slots.py docstring).

    Greedy outputs are token-identical to an unpadded per-request reference
    (the wave engine at batch_size=1): admission prefills each prompt at
    its exact length through a B=1 scratch cache, and lanes never interact
    — per-lane positions keep attention exact, and rwkv/mamba/MoE-decode
    paths are lane-independent by construction.  Distinct prompt lengths
    compile distinct prefill executables (bucket upstream if that matters).

    With ``EngineConfig.prefill_chunk_len`` set, admission prefill is
    CHUNK-INTERLEAVED instead: up to ``prefill_lanes`` PrefillLane state
    machines each prefill one prompt through fixed-shape segments
    (slots.chunk_schedule), the tick loop advances at most ONE chunk
    between decode ticks (round-robin across lanes), and a lane admits
    into a slot only when fully prefilled.  Resident lanes therefore
    never stall for more than one chunk on a long-prompt admission — the
    lockstep pathology whole-prompt admission readmits — while greedy
    outputs stay token-identical to whole-prompt prefill and the compiled
    prefill shapes collapse from one-per-prompt-length to one per segment
    length ({chunk_len} plus descending powers of two for remainders).
    """

    #: smoothing for the observed tick-latency EMA the shed predicate and
    #: watchdog read (matches core.scheduler.Plan.ema)
    TICK_EMA = 0.3

    def __init__(self, model: Model, params: Any, *,
                 config: EngineConfig | None = None, sensor=None,
                 extra_plans: dict[str, Callable] | None = None,
                 clock: Callable[[], float] = None, **legacy):
        """All queue/retry/ladder/fault/chunk knobs live on ``config``
        (EngineConfig, see its docstring); the old per-engine kwargs are
        accepted as deprecated aliases.  ``sensor``/``extra_plans``/
        ``clock`` stay real kwargs — they are collaborator objects, not
        configuration."""
        config = _resolve_config("SlotEngine", config, legacy, _SLOT_ALIASES)
        self.config = config
        n_slots, max_seq = config.n_slots, config.max_seq
        super().__init__(model, params, batch_size=n_slots, max_seq=max_seq,
                         pool_capacity=1, sensor=sensor,
                         extra_plans=extra_plans, per_lane_pos=True)
        self.n_slots = n_slots
        self.clock = clock or time.monotonic
        self.queue = RequestQueue(config.queue_capacity, clock=self.clock)
        # completed Results land here until the caller consumes them with
        # take_finished() — long-running submit()/stream() users must drain
        # it, or host memory grows with every retired request
        self.finished: dict[int, Result] = {}

        # -- chunked prefill (admission interleaving) -----------------------
        w = self.cfg.sliding_window or 0
        #: longest prompt the CHUNKED path serves token-identically: a
        #: windowed KV ring starts evicting once the prompt outruns the
        #: cache seq axis, and mid-chunk queries then see less in-window
        #: history than whole-prompt flash attention would give them.
        #: Longer windowed prompts fall back to whole-prompt admission.
        self._chunk_safe_len = min(max_seq, w) if w else max_seq
        self._chunk_len = config.prefill_chunk_len
        self.prefill_lanes = config.prefill_lanes
        chunked = self._chunk_len is not None
        if chunked:
            if self.cfg.n_vis_tokens:
                raise ValueError(
                    "chunked prefill cannot serve vis-token prompts (the "
                    "vision prefix is not sliceable); keep "
                    "prefill_chunk_len=None")
            if not 0 < self._chunk_len <= self._chunk_safe_len:
                raise ValueError(
                    f"prefill_chunk_len {self._chunk_len} outside (0, "
                    f"{self._chunk_safe_len}] — chunks longer than the "
                    "cache seq axis would scatter duplicate ring slots")
            if self.prefill_lanes < 1:
                raise ValueError(
                    f"prefill_lanes {self.prefill_lanes} must be >= 1")

        # B=1 scratch the admission prefill runs through (donated per
        # dispatch).  Whole-prompt mode keeps ONE permanently checked-out
        # buffer; chunked mode pools ``prefill_lanes`` of them (one per
        # concurrent PrefillLane, checked out at lane start and returned —
        # zeroed through the pool's donated reset — at admission, abort or
        # failure), plus the persistent whole-prompt buffer when windowed
        # fallbacks are possible.  Either way the pool is built ONCE:
        # ``buffers_built`` stays at capacity for the life of the engine.
        scratch_abs, _ = split(jax.eval_shape(
            lambda: model.init_cache(1, max_seq)))
        self._scratch_abs = scratch_abs
        self._fallback = chunked and bool(w) and self._chunk_safe_len < max_seq
        self._scratch_pool = StatePool(
            scratch_abs, capacity=(self.prefill_lanes + int(self._fallback)
                                   if chunked else 1))
        self._scratch = (self._scratch_pool.checkout()
                         if not chunked or self._fallback else None)

        def prefill_sample(p, c, b):
            # zero the donated scratch first — rwkv/mamba prefill consumes
            # the cache as its initial state, so a previous occupant's
            # state must not leak into the next prompt — then sample the
            # prompt's first greedy token, all in one dispatch
            c = jax.tree.map(lambda a: a * 0, c)
            logits, c = steps_lib.prefill_step(self.cfg, p, c, b)
            return steps_lib.greedy_sample(logits)[..., 0], c

        def prefill_chunk_sample(p, c, b, first):
            # ``first`` is a TRACED scalar bool, so chunk 0 (zero the
            # scratch, prefill_sample's reset) and continuation chunks
            # share ONE executable per segment length — the one-shape-per-
            # (chunk_len,) contract
            c = jax.tree.map(lambda a: jnp.where(first, a * 0, a), c)
            logits, c = steps_lib.chunked_prefill_step(self.cfg, p, c, b)
            return steps_lib.greedy_sample(logits)[..., 0], c

        # pre-create the serving instruments so metrics snapshots (and the
        # end-of-stream serve/metrics trace event) always carry the full
        # schema, zero-valued counters included
        for name in ("serving/ticks", "serving/tokens", "serving/retired",
                     "serving/deadline_miss", "serving/quarantined",
                     "serving/retries", "serving/shed"):
            self.metrics.counter(name)
        self.metrics.histogram("serving/ttft_s")
        self.metrics.histogram("serving/tbt_s")
        if chunked:
            self.metrics.histogram("serving/prefill_chunk_s")

        token_tail = ((self.cfg.n_codebooks,) if self.cfg.n_codebooks
                      else ())
        self._prefill_sample = jax.jit(prefill_sample, donate_argnums=(1,))
        self._prefill_chunk = jax.jit(prefill_chunk_sample,
                                      donate_argnums=(1,))
        # device-resident chunk-0 flags, uploaded once and reused — the
        # chunked path keeps the no-per-dispatch-upload property
        self._first_true = jnp.asarray(True)
        self._first_false = jnp.asarray(False)
        self._lanes: list[PrefillLane] = []
        self._rr = 0                 # round-robin cursor over live lanes
        self.manager = SlotManager(
            self.pool.checkout(), n_slots, token_tail=token_tail,
            clock=self.clock)

        # -- fault tolerance ------------------------------------------------
        ladder = config.ladder
        unknown = set(ladder or []) - set(self.scheduler.plans)
        if unknown:
            raise ValueError(
                f"ladder names unregistered plans: {sorted(unknown)}")
        self.scheduler.ladder = list(ladder or [])
        self.retry_budget = config.retry_budget
        self.retry_backoff_s = config.retry_backoff_s
        self.tick_slo_s = config.tick_slo_s
        self.slo_breach_ticks = config.slo_breach_ticks
        self.slo_recover_ticks = config.slo_recover_ticks
        self.shed_margin = config.shed_margin
        faults = config.faults
        self.injector = None if faults is None else faults_lib.FaultInjector(
            faults, n_slots, vocab=self.cfg.vocab, max_seq=max_seq,
            token_tail=token_tail)
        # the all-False poison mask is uploaded ONCE and reused every
        # healthy tick, so the guard keeps the no-per-tick-upload property;
        # a real mask is uploaded only on the fault ticks themselves
        self._no_poison = jnp.zeros((n_slots,), bool)
        self._attempts: dict[int, int] = {}   # uid -> retries consumed
        self._retry_backlog: list[tuple[float, Request]] = []
        self._tick_ema: float | None = None
        self._breach_ticks = 0
        self._healthy_ticks = 0

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        # every plan is wrapped with the active-mask select (free/finished
        # lanes keep their state untouched), the per-lane finite guard and
        # greedy sampling, so one dispatch per tick yields
        # (sampled tokens, lane_ok, cache) directly
        def masked(fn=None):
            def plan(p, c, b):
                step = None if fn is None else (
                    lambda _cfg, p_, c_, b_: fn(p_, c_, b_))
                logits, lane_ok, cache = steps_lib.guarded_decode_step(
                    self.cfg, p, c, b, step_fn=step)
                return steps_lib.greedy_sample(logits), lane_ok, cache
            return plan

        plans = {"decode/base": masked()}
        plans.update({n: masked(fn) for n, fn in extra.items()})
        return plans

    # ------------------------------------------------------------------
    def _validate(self, req: Request) -> None:
        """Reject requests that cannot fit their lane BEFORE they queue —
        decode writes token ``i`` at position prompt_len + i, and an
        out-of-range lane scatter would be silently dropped, not clamped."""
        if req.max_new_tokens <= 0:
            return                        # completes without touching a lane
        s = np.asarray(req.prompt).shape[-1]
        if not 0 < s <= self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt length {s} outside (0, "
                f"{self.max_seq}]")
        if s + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt {s} + max_new_tokens "
                f"{req.max_new_tokens} - 1 exceeds max_seq {self.max_seq}")

    def submit(self, req: Request) -> bool:
        """Queue one request; raises QueueFull (backpressure) when bounded
        queue capacity is reached, ValueError when it cannot fit a lane.
        Returns False — with an immediate ``finish_reason='deadline'``
        Result published to ``finished`` — when the request is dead on
        arrival (its deadline already passed)."""
        self._validate(req)
        if not self.queue.submit(req):
            self.metrics.counter("serving/deadline_miss").inc()
            self._terminal(req, FinishReason.DEADLINE)
            return False
        return True

    def _admit_one(self, index: int, req: Request) -> TokenEvent:
        prompt = np.asarray(req.prompt, np.int32)
        t0 = time.perf_counter()
        tok0, self._scratch = self._prefill_sample(
            self.params, self._scratch,
            self._prefill_batch(prompt.reshape((1,) + prompt.shape)))
        tok0 = tok0[0]                       # () or (K,), device array
        prefill_s = time.perf_counter() - t0
        tok0_np = np.asarray(tok0, np.int32)  # blocks: token host-visible
        ttft_s = time.perf_counter() - t0     # admit -> first token
        self.manager.admit(index, req, self._scratch, tok0, prefill_s,
                           ttft_s=ttft_s)
        self.metrics.histogram("serving/ttft_s").observe(ttft_s)
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/admit", uid=req.uid, slot=index,
                         prompt_len=int(prompt.shape[-1]),
                         prefill_s=prefill_s, ttft_s=ttft_s)
        return TokenEvent(req.uid, tok0_np, 0,
                          done=(req.max_new_tokens <= 1))

    # -- fault-tolerance plumbing --------------------------------------
    def _terminal(self, req: Request, reason: str) -> TokenEvent:
        """Publish a tokenless terminal Result (queue expiry, dead-on-
        arrival deadline, shed, failure out of retries) and return its
        stream event."""
        self._attempts.pop(req.uid, None)
        self.finished[req.uid] = Result(req.uid, self.manager.empty_tokens(),
                                        0.0, 0.0, [], finish_reason=reason)
        return TokenEvent(req.uid, None, 0, done=True, finish_reason=reason)

    def _finish(self, res: Result) -> None:
        """Adopt a retired lane's Result — the one place lane retirement
        updates the metrics and retry bookkeeping."""
        self.metrics.counter("serving/retired").inc()
        self._attempts.pop(res.uid, None)
        self.finished[res.uid] = res

    def _fail_or_retry(self, req: Request, now: float) -> str | None:
        """Shared quarantine / prefill-failure disposition.  Consumes one
        unit of ``retry_budget`` when available: the request re-enters the
        queue after exponential backoff (``retry_backoff_s * 2**attempt``)
        and restarts FROM PREFILL — a retried greedy request therefore
        still produces exactly its fault-free tokens.  Returns None on
        retry, otherwise the terminal finish_reason (ERROR with no budget,
        RETRIES_EXHAUSTED once the budget is spent)."""
        attempts = self._attempts.get(req.uid, 0)
        if attempts < self.retry_budget:
            self._attempts[req.uid] = attempts + 1
            self.metrics.counter("serving/retries").inc()
            ready = now + self.retry_backoff_s * (2.0 ** attempts)
            self._retry_backlog.append((ready, req))
            return None
        return (FinishReason.RETRIES_EXHAUSTED if self.retry_budget > 0
                else FinishReason.ERROR)

    def _prefill_failed(self, req: Request, now: float, err: Exception
                        ) -> Iterator[TokenEvent]:
        """Containment for an admission prefill that raised: emit the
        serve/fault event, then retry or terminate the request."""
        injected = isinstance(err, faults_lib.InjectedFault)
        if not injected and self._scratch is not None and any(
                getattr(a, "is_deleted", lambda: False)()
                for a in jax.tree.leaves(self._scratch)):
            # a REAL prefill exception may have consumed the donated
            # scratch mid-dispatch; rebuild it so the next admission still
            # works.  Injected faults raise before the dispatch and never
            # take this path, so chaos runs stay zero-allocation.
            self._scratch = make_buffer(self._scratch_abs)
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/fault", kind="prefill", uid=req.uid,
                         injected=injected, error=repr(err))
        reason = self._fail_or_retry(req, now)
        if reason is not None:
            yield self._terminal(req, reason)

    # -- chunked admission (the tentpole) ------------------------------
    def _lane_failed(self, lane: PrefillLane, now: float, err: Exception
                     ) -> Iterator[TokenEvent]:
        """Containment for a chunked-prefill attempt that raised: the
        lane's PARTIAL state is discarded (its scratch returns to the pool
        through the donated zeroing reset), so a retry restarts from chunk
        0 with a clean cache — token-identical to an unfaulted admission."""
        injected = isinstance(err, faults_lib.InjectedFault)
        cache = lane.cache
        if not injected and any(
                getattr(a, "is_deleted", lambda: False)()
                for a in jax.tree.leaves(cache)):
            # same rebuild rule as _prefill_failed: only a REAL exception
            # can strand a consumed donated buffer
            cache = make_buffer(self._scratch_abs)
        self._scratch_pool.give_back(cache)
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/fault", kind="prefill",
                         uid=lane.request.uid, injected=injected,
                         chunk=lane.chunks_done, error=repr(err))
        reason = self._fail_or_retry(lane.request, now)
        if reason is not None:
            yield self._terminal(lane.request, reason)

    def _advance_lane(self, lane: PrefillLane, now: float
                      ) -> Iterator[TokenEvent]:
        """Run ONE prefill chunk for ``lane``; on the final chunk, admit
        the fully-prefilled request into a free slot (the invariant
        ``len(self._lanes) <= free slots`` guarantees one exists — slots
        are only ever occupied BY lane admission while lanes are live)."""
        mgr = self.manager
        req = lane.request
        inj = self.injector
        tracer = trace_lib.get_tracer()
        seg = lane.schedule[0]
        try:
            if inj is not None and inj.take_prefill_fault(
                    req.uid, lane.chunks_done):
                # raised BEFORE the dispatch: the lane cache is untouched
                raise faults_lib.InjectedFault(
                    f"injected prefill fault, uid={req.uid}, "
                    f"chunk={lane.chunks_done}")
            toks = lane.prompt[..., lane.filled:lane.filled + seg]
            first = (self._first_true if lane.chunks_done == 0
                     else self._first_false)
            t0 = time.perf_counter()
            tok, lane.cache = self._prefill_chunk(
                self.params, lane.cache,
                self._prefill_batch(toks.reshape((1,) + toks.shape)), first)
            tok = jax.block_until_ready(tok)
            chunk_s = time.perf_counter() - t0
        except Exception as err:      # containment: never escapes
            self._lanes.remove(lane)
            yield from self._lane_failed(lane, now, err)
            return
        lane.schedule.pop(0)
        lane.filled += seg
        lane.chunks_done += 1
        lane.prefill_s += chunk_s
        lane.last_tok = tok[0]               # () or (K,), device array
        self.metrics.histogram("serving/prefill_chunk_s").observe(chunk_s)
        if tracer.enabled:
            tracer.event("serve/prefill_chunk", uid=req.uid,
                         chunk=lane.chunks_done - 1, seg_len=seg,
                         filled=lane.filled, chunk_s=chunk_s)
        if not lane.done:
            return
        # fully prefilled: admit into a free slot and release the scratch
        self._lanes.remove(lane)
        idx = mgr.free_indices()[0]
        tok0_np = np.asarray(lane.last_tok, np.int32)
        ttft_s = time.perf_counter() - lane.t_start
        mgr.admit(idx, req, lane.cache, lane.last_tok, lane.prefill_s,
                  ttft_s=ttft_s)
        self._scratch_pool.give_back(lane.cache)
        self.metrics.histogram("serving/ttft_s").observe(ttft_s)
        if tracer.enabled:
            tracer.event("serve/admit", uid=req.uid, slot=idx,
                         prompt_len=int(lane.prompt.shape[-1]),
                         prefill_s=lane.prefill_s, ttft_s=ttft_s,
                         chunks=lane.chunks_done)
        ev = TokenEvent(req.uid, tok0_np, 0, done=(req.max_new_tokens <= 1))
        yield ev
        if ev.done:
            self._finish(mgr.retire(idx))

    def _admit_chunked(self, now: float, refill) -> Iterator[TokenEvent]:
        """One scheduling round of chunk-interleaved admission: abort
        deadline-expired lanes, start new lanes while scratch buffers AND
        target slots are both free, then advance at most ONE chunk total
        (round-robin across live lanes) before the decode tick runs."""
        mgr = self.manager
        metrics = self.metrics
        inj = self.injector
        tracer = trace_lib.get_tracer()

        # partially-prefilled requests past their deadline abort here —
        # the partial state is discarded and buffers_built is untouched
        for lane in [ln for ln in self._lanes
                     if ln.request.deadline_s is not None
                     and ln.request.deadline_s <= now]:
            self._lanes.remove(lane)
            self._scratch_pool.give_back(lane.cache)
            metrics.counter("serving/deadline_miss").inc()
            yield self._terminal(lane.request, FinishReason.DEADLINE)

        # start lanes: never more live lanes than prefill_lanes OR free
        # slots — every lane must have a slot to land in when it finishes
        while (len(self._lanes) < self.prefill_lanes
               and len(self._lanes) < len(mgr.free_indices())):
            yield from refill()
            req = self.queue.pop()
            if req is None:
                break
            if req.max_new_tokens <= 0:
                # zero-budget request: complete without touching a lane
                self.finished[req.uid] = Result(
                    req.uid, mgr.empty_tokens(), 0.0, 0.0, [])
                yield TokenEvent(req.uid, None, 0, done=True,
                                 finish_reason=FinishReason.LENGTH)
                continue
            prompt = np.asarray(req.prompt, np.int32)
            if prompt.shape[-1] > self._chunk_safe_len:
                # windowed prompt past the cache seq axis: chunked replay
                # through the ring is not token-identical, so this one
                # admission takes the legacy whole-prompt path (and eats
                # the full stall — the documented trade)
                idx = mgr.free_indices()[0]
                try:
                    if inj is not None and inj.take_prefill_fault(req.uid):
                        raise faults_lib.InjectedFault(
                            f"injected prefill fault, uid={req.uid}")
                    ev = self._admit_one(idx, req)
                except Exception as err:
                    yield from self._prefill_failed(req, now, err)
                    continue
                yield ev
                if ev.done:
                    self._finish(mgr.retire(idx))
                continue
            self._lanes.append(PrefillLane(
                request=req, cache=self._scratch_pool.checkout(),
                schedule=chunk_schedule(prompt.shape[-1], self._chunk_len),
                prompt=prompt, t_start=time.perf_counter()))
            if tracer.enabled:
                tracer.event("serve/prefill_start", uid=req.uid,
                             prompt_len=int(prompt.shape[-1]),
                             n_chunks=len(self._lanes[-1].schedule))

        # the chunk budget: ONE fixed-shape prefill dispatch per tick-loop
        # iteration, shared round-robin — a short prompt behind a long
        # adversary waits O(its own chunks), not the adversary's prefill
        if self._lanes:
            self._rr += 1
            yield from self._advance_lane(
                self._lanes[self._rr % len(self._lanes)], now)

    def _watchdog(self, observed_s: float, tick: int) -> None:
        """Tick-latency watchdog driving the degradation ladder: after
        ``slo_breach_ticks`` consecutive ticks over ``tick_slo_s`` the
        scheduler steps one rung down (sched/degrade in the trace); after
        ``slo_recover_ticks`` consecutive healthy ticks it steps back up."""
        ema = self._tick_ema
        self._tick_ema = (observed_s if ema is None else
                          (1 - self.TICK_EMA) * ema
                          + self.TICK_EMA * observed_s)
        if self.tick_slo_s is None:
            return
        if observed_s > self.tick_slo_s:
            self._breach_ticks += 1
            self._healthy_ticks = 0
            if self._breach_ticks >= self.slo_breach_ticks:
                self.scheduler.degrade(reason=f"tick_slo@{tick}")
                self._breach_ticks = 0
        else:
            self._breach_ticks = 0
            self._healthy_ticks += 1
            if (self.scheduler.level > 0
                    and self._healthy_ticks >= self.slo_recover_ticks):
                self.scheduler.recover()
                self._healthy_ticks = 0

    def stream(self, requests: list[Request] | None = None
               ) -> Iterator[TokenEvent]:
        """Run the continuous-batching loop, yielding one TokenEvent per
        generated token (plus terminal events), until queue and slots
        drain.  ``requests`` are fed into the bounded queue as space frees
        — external callers use ``submit`` and get backpressure instead.

        Results are published through ``self.finished`` as slots retire.
        """
        for req in requests or []:
            self._validate(req)          # fail fast, not mid-stream
        pending = collections.deque(requests or [])
        mgr = self.manager
        metrics = self.metrics
        inj = self.injector
        tick = 0
        while (pending or len(self.queue) or mgr.any_occupied
               or self._retry_backlog or self._lanes):
            now = self.clock()
            tracer = trace_lib.get_tracer()

            # injected queue floods land first: synthetic dead weight
            # competing with real work for bounded queue space.  A flood
            # bouncing off a full queue is the defined behaviour
            # (backpressure), same as a rejected client — dropped, not
            # tracked.
            if inj is not None:
                for req in inj.flood_requests(tick, now):
                    if tracer.enabled:
                        tracer.event("serve/fault", kind="flood", tick=tick,
                                     uid=req.uid)
                    try:
                        if not self.queue.submit(req, now=now):
                            metrics.counter("serving/deadline_miss").inc()
                            yield self._terminal(req, FinishReason.DEADLINE)
                    except QueueFull:
                        pass

            # quarantined requests whose backoff elapsed re-enter the
            # queue (ahead of fresh `pending` work — they were admitted
            # once already)
            if self._retry_backlog:
                still: list[tuple[float, Request]] = []
                for ready_t, req in self._retry_backlog:
                    if ready_t > now or self.queue.full:
                        still.append((ready_t, req))
                    elif not self.queue.submit(req, now=now):
                        metrics.counter("serving/deadline_miss").inc()
                        yield self._terminal(req, FinishReason.DEADLINE)
                self._retry_backlog = still

            def refill_and_expire():
                """Top the queue up from `pending`, then drop anything whose
                deadline already passed — every pop below sees an expired-
                free queue, including mid-admission refills.  A pending
                request dead on arrival terminates immediately without
                queueing."""
                while pending and not self.queue.full:
                    req = pending.popleft()
                    if not self.queue.submit(req, now=now):
                        metrics.counter("serving/deadline_miss").inc()
                        yield self._terminal(req, FinishReason.DEADLINE)
                for req in self.queue.expire(now):
                    metrics.counter("serving/deadline_miss").inc()
                    yield self._terminal(req, FinishReason.DEADLINE)

            yield from refill_and_expire()
            # resident lanes past their deadline retire with what they have
            for idx in mgr.expired_indices(now):
                res = mgr.retire(idx, finish_reason=FinishReason.DEADLINE)
                metrics.counter("serving/deadline_miss").inc()
                self._finish(res)
                yield TokenEvent(res.uid, None, res.tokens.shape[-1],
                                 done=True,
                                 finish_reason=FinishReason.DEADLINE)

            # degradation ladder, shed half: once degraded, queued requests
            # whose deadlines are provably unmeetable under the observed
            # tick latency are dropped now instead of wasting lane time
            # before expiring anyway
            if self.scheduler.level > 0 and self._tick_ema is not None:
                horizon = now + self.shed_margin * self._tick_ema
                for req in self.queue.shed(
                        lambda r: r.deadline_s is not None
                        and r.deadline_s <= horizon):
                    metrics.counter("serving/shed").inc()
                    if tracer.enabled:
                        tracer.event("serve/shed", uid=req.uid, tick=tick,
                                     deadline_s=req.deadline_s,
                                     tick_ema_s=self._tick_ema)
                    yield self._terminal(req, FinishReason.SHED)

            # step-granular admission — chunk-interleaved (at most one
            # prefill chunk before the decode tick) or whole-prompt
            if self._chunk_len is not None:
                yield from self._admit_chunked(now, refill_and_expire)
            else:
                for idx in mgr.free_indices():
                    yield from refill_and_expire()
                    req = self.queue.pop()
                    if req is None:
                        break
                    if req.max_new_tokens <= 0:
                        # zero-budget request: complete without a lane
                        self.finished[req.uid] = Result(
                            req.uid, mgr.empty_tokens(), 0.0, 0.0, [])
                        yield TokenEvent(req.uid, None, 0, done=True,
                                         finish_reason=FinishReason.LENGTH)
                        continue
                    try:
                        if (inj is not None
                                and inj.take_prefill_fault(req.uid)):
                            # raised BEFORE the dispatch: the donated
                            # scratch is untouched, exactly the guarantee
                            # InjectedFault documents
                            raise faults_lib.InjectedFault(
                                f"injected prefill fault, uid={req.uid}")
                        ev = self._admit_one(idx, req)
                    except Exception as err:  # containment: never escapes
                        yield from self._prefill_failed(req, now, err)
                        continue
                    yield ev
                    if ev.done:
                        self._finish(mgr.retire(idx))

            queue_depth = len(self.queue)
            occupied = sum(1 for s in mgr.slots if s.occupied)
            metrics.gauge("serving/queue_depth").set(float(queue_depth))
            metrics.gauge("serving/occupancy").set(occupied / mgr.n_slots)

            if not mgr.active_mask().any():
                if (pending or len(self.queue) or self._retry_backlog
                        or self._lanes):
                    # only expiries/zero-token admissions/backoffs/partial
                    # prefills left; keep looping — lanes advance one
                    # chunk per iteration even with no decode to interleave
                    continue
                break

            # ONE fused masked decode tick across all lanes — the span
            # wraps choose + dispatch + host copy, so the per-tick
            # sched/choose event nests under serve/tick in the trace
            span = (tracer.span("serve/tick", tick=tick,
                                queue_depth=queue_depth, occupied=occupied)
                    if tracer.enabled else trace_lib.NULL_SPAN)
            with span:
                d = self.scheduler.choose()
                plan = self.scheduler.plans[d.plan]
                batch = mgr.tick_batch()
                lanes = inj.poison_lanes(tick) if inj is not None else ()
                if lanes:
                    mask = np.zeros((self.n_slots,), bool)
                    mask[list(lanes)] = True
                    batch["poison"] = jnp.asarray(mask)
                    if tracer.enabled:
                        for lane in lanes:
                            tracer.event("serve/fault", kind="poison",
                                         tick=tick, lane=lane)
                else:
                    batch["poison"] = self._no_poison
                t0 = time.perf_counter()
                sampled_dev, lane_ok_dev, mgr.cache = plan.fn(
                    self.params, mgr.cache, batch)
                mgr.set_sampled(sampled_dev)
                sampled = np.asarray(sampled_dev)  # blocks; 1 copy per tick
                lane_ok = np.asarray(lane_ok_dev)
                tick_s = time.perf_counter() - t0
                extra_s = inj.slow_s(tick) if inj is not None else 0.0
                if extra_s and tracer.enabled:
                    tracer.event("serve/fault", kind="slow", tick=tick,
                                 extra_s=extra_s)
                observed_s = tick_s + extra_s
                plan.observe(observed_s, d.load)
                span.set(plan=d.plan, load=d.load, tick_s=tick_s,
                         observed_s=observed_s)
            metrics.counter("serving/ticks").inc()
            self._watchdog(observed_s, tick)

            # quarantine: any ACTIVE lane whose finite guard tripped
            # retires NOW, before its poisoned token could be recorded —
            # the donated lane reset inside retire() zeroes just that
            # lane, so its neighbours and the zero-allocation invariant
            # are untouched
            for s in [s for s in mgr.slots
                      if s.occupied and not lane_ok[s.index]]:
                req = s.request
                metrics.counter("serving/quarantined").inc()
                reason = self._fail_or_retry(req, now)
                res = mgr.retire(s.index,
                                 finish_reason=reason or FinishReason.ERROR)
                if tracer.enabled:
                    tracer.event("serve/quarantine", uid=req.uid,
                                 slot=s.index, tick=tick,
                                 action="retry" if reason is None
                                 else reason)
                if reason is None:
                    # retry path: partial output discarded — the retry
                    # restarts from prefill and regenerates the same
                    # greedy tokens
                    continue
                self._finish(res)
                yield TokenEvent(req.uid, None, res.tokens.shape[-1],
                                 done=True, finish_reason=reason)
            tick += 1

            just_active = [s.index for s in mgr.slots
                           if s.occupied and s.remaining > 0]
            done_idx = set(mgr.record(sampled, d.plan))
            metrics.counter("serving/tokens").inc(len(just_active))
            token_t = time.perf_counter()
            tbt = metrics.histogram("serving/tbt_s")
            for idx in just_active:
                s = mgr.slots[idx]
                tbt.observe(token_t - s.last_token_t)
                s.last_token_t = token_t
                yield TokenEvent(s.request.uid, np.asarray(sampled[idx],
                                                           np.int32),
                                 len(s.tokens) - 1, done=idx in done_idx)
            for idx in done_idx:
                self._finish(mgr.retire(idx))

        # one summary record per drained stream: every counter (including
        # zero-valued deadline_miss), gauge and histogram summary
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/metrics", **metrics.snapshot())

    def take_finished(self) -> dict[int, Result]:
        """Pop and return every completed Result (uid -> Result).  The
        consumption half of the streaming API: call it periodically from a
        long-running submit()/stream() loop to keep host memory bounded."""
        out, self.finished = self.finished, {}
        return out

    def serve(self, requests: list[Request],
              on_token: Callable[[TokenEvent], None] | None = None
              ) -> list[Result]:
        """Convenience wrapper: stream everything, return per-request
        Results in submission order."""
        self.finished = {}
        for ev in self.stream(requests):
            if on_token is not None:
                on_token(ev)
        done = self.take_finished()
        return [done[r.uid] for r in requests]
