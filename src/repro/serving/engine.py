"""Serving engines with MobiRNN-style runtime policies.

The paper mechanisms are first-class here:
  * preallocated state pools (core/state.StatePool) — decode caches are
    built once and reset in place through donated jits; no allocation on the
    serving path, pool exhaustion = explicit backpressure;
  * load-aware dispatch (core/scheduler.Scheduler) — multiple decode plans
    are registered and the predicted-fastest under current load runs each
    tick (paper Fig 7);
  * fixed-shape batching — the decode step has one shape for the life of
    the engine.

Two engines share that substrate:

``Engine`` — the coarse WAVE engine: requests are packed into lockstep
waves of ``batch_size``; every request pads to the longest prompt and the
longest ``max_new_tokens`` in its wave.  Short waves are padded with
zero-length dummy requests (an inactive lane, not a duplicated real
request).  Kept as the baseline the benchmarks compare against.

``SlotEngine`` — slot-resident CONTINUOUS batching (serving/slots.py): the
batch axis is B independent slots over one preallocated cache; requests are
admitted from a bounded queue into free slots at step granularity, decode
runs one fused masked step across all lanes per tick, and retirement resets
just that lane and immediately admits the next request.  Tokens stream out
per tick (``stream``/``on_token``) instead of arriving all at once.  This
is the engine the ROADMAP's heavy-traffic north star builds on.

Both engines are modality-generic: they serve any registry.Model whose
config family is text-like (dense/moe/ssm/hybrid/vlm/audio all decode
token ids).
"""
from __future__ import annotations

import collections
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.core.state import StatePool
from repro.obs import trace as trace_lib
from repro.obs.metrics import Metrics
from repro.models.registry import Model
from repro.partitioning import split
from repro.serving.slots import (QueueFull, Request, RequestQueue, Result,
                                 SlotManager, TokenEvent)
from repro import steps as steps_lib


class _EngineBase:
    """Shared substrate: cache pool, prefill jit, decode-plan scheduler."""

    def __init__(self, model: Model, params: Any, *, batch_size: int,
                 max_seq: int, pool_capacity: int, sensor,
                 extra_plans: dict[str, Callable] | None, per_lane_pos: bool):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq

        cache_annot = jax.eval_shape(
            lambda: model.init_cache(batch_size, max_seq))
        cache_abs, _ = split(cache_annot)
        if per_lane_pos:
            # continuous batching: each lane decodes at its own position
            cache_abs = dict(cache_abs, pos=jax.ShapeDtypeStruct(
                (batch_size,), jnp.int32))
        self.pool = StatePool(cache_abs, capacity=pool_capacity)

        # shape-polymorphic: the same jit serves (B, S) wave prefills and
        # (1, S) per-slot admission prefills (one compile per shape)
        self._prefill = jax.jit(
            lambda p, c, b: steps_lib.prefill_step(self.cfg, p, c, b),
            donate_argnums=(1,))

        self.scheduler = Scheduler(sensor or SyntheticLoadSensor(0.0))
        for name, fn in self._decode_plans(extra_plans or {}).items():
            self.scheduler.register(
                Plan(name, jax.jit(fn, donate_argnums=(1,)), shared=True))

        # serving metrics are ALWAYS on: obs.metrics instruments are plain
        # host ints/deques, so they cannot violate the zero-allocation
        # serving invariant (tests assert buffers_built stays at capacity
        # with metrics enabled); tracing stays opt-in via obs.trace
        self.metrics = Metrics()

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        raise NotImplementedError

    def _prefill_batch(self, toks: np.ndarray) -> dict:
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.n_vis_tokens:
            batch["vis_embeds"] = jnp.zeros(
                (toks.shape[0], self.cfg.n_vis_tokens, self.cfg.vis_dim),
                jnp.dtype(self.cfg.dtype))
        return batch


# ---------------------------------------------------------------------------
# Wave engine (baseline)
# ---------------------------------------------------------------------------
class Engine(_EngineBase):
    """Lockstep wave engine — the coarse-batching baseline."""

    def __init__(self, model: Model, params: Any, *, batch_size: int = 4,
                 max_seq: int = 128, pool_capacity: int = 2,
                 sensor=None, extra_plans: dict[str, Callable] | None = None):
        super().__init__(model, params, batch_size=batch_size,
                         max_seq=max_seq, pool_capacity=pool_capacity,
                         sensor=sensor, extra_plans=extra_plans,
                         per_lane_pos=False)

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        plans = {"decode/base":
                 lambda p, c, b: steps_lib.decode_step(self.cfg, p, c, b)}
        plans.update(extra)
        return plans

    # ------------------------------------------------------------------
    def _dummy_request(self) -> Request:
        """Zero-length, zero-token filler for ragged wave tails — an
        inactive lane, NOT a duplicate of a real request."""
        shape = ((self.cfg.n_codebooks, 0) if self.cfg.n_codebooks
                 else (0,))
        return Request(uid=-1, prompt=np.zeros(shape, np.int32),
                       max_new_tokens=0)

    def _pad_prompts(self, reqs: list[Request]) -> tuple[np.ndarray, int]:
        lens = [r.prompt.shape[-1] for r in reqs]
        s = max(lens)
        shape = ((self.batch_size, self.cfg.n_codebooks, s)
                 if self.cfg.n_codebooks else (self.batch_size, s))
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, ..., s - r.prompt.shape[-1]:] = r.prompt  # left-pad
        return toks, s

    def serve(self, requests: list[Request]) -> list[Result]:
        """Serve all requests in fixed-shape waves of `batch_size`."""
        results: list[Result] = []
        for i in range(0, len(requests), self.batch_size):
            wave = requests[i:i + self.batch_size]
            pad = self.batch_size - len(wave)
            wave_padded = wave + [self._dummy_request()] * pad
            results.extend(self._serve_wave(wave_padded)[: len(wave)])
        return results

    def _serve_wave(self, reqs: list[Request]) -> list[Result]:
        cache = self.pool.checkout()
        toks, _ = self._pad_prompts(reqs)
        batch = self._prefill_batch(toks)

        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(
            self._prefill(self.params, cache, batch))
        t_prefill = time.perf_counter() - t0

        max_new = max(r.max_new_tokens for r in reqs)
        outs = []
        decisions = []
        tracer = trace_lib.get_tracer()
        wave_span = (tracer.span("serve/wave", n_reqs=len(reqs),
                                 max_new=max_new, prefill_s=t_prefill)
                     if tracer.enabled else trace_lib.NULL_SPAN)
        # prefill logits keep a singleton seq axis before the vocab dim
        tok = steps_lib.greedy_sample(logits)[..., 0]
        t0 = time.perf_counter()
        with wave_span:
            for _ in range(max_new):
                outs.append(np.asarray(tok))
                d = self.scheduler.choose()
                decisions.append(d.plan)
                plan = self.scheduler.plans[d.plan]
                t1 = time.perf_counter()
                logits, cache = jax.block_until_ready(
                    plan.fn(self.params, cache, {"tokens": tok}))
                plan.observe(time.perf_counter() - t1, d.load)
                tok = steps_lib.greedy_sample(logits)
            t_decode = time.perf_counter() - t0
            wave_span.set(decode_s=t_decode)
        self.pool.give_back(cache)
        self.metrics.counter("serving/waves").inc()
        self.metrics.histogram("serving/wave_prefill_s").observe(t_prefill)
        self.metrics.histogram("serving/wave_decode_s").observe(t_decode)

        # (B, [K,] max_new); toks[..., :0] covers an all-zero-budget wave
        gen = (np.stack(outs, axis=-1) if outs else toks[..., :0])
        return [Result(r.uid, gen[j, ..., :r.max_new_tokens], t_prefill,
                       t_decode, decisions)
                for j, r in enumerate(reqs)]


# ---------------------------------------------------------------------------
# Slot engine (continuous batching)
# ---------------------------------------------------------------------------
class SlotEngine(_EngineBase):
    """Slot-resident continuous batching (see serving/slots.py docstring).

    Greedy outputs are token-identical to an unpadded per-request reference
    (the wave engine at batch_size=1): admission prefills each prompt at
    its exact length through a B=1 scratch cache, and lanes never interact
    — per-lane positions keep attention exact, and rwkv/mamba/MoE-decode
    paths are lane-independent by construction.  Distinct prompt lengths
    compile distinct prefill executables (bucket upstream if that matters).
    """

    def __init__(self, model: Model, params: Any, *, n_slots: int = 4,
                 max_seq: int = 128, queue_capacity: int = 16,
                 sensor=None, extra_plans: dict[str, Callable] | None = None,
                 clock: Callable[[], float] = None):
        super().__init__(model, params, batch_size=n_slots, max_seq=max_seq,
                         pool_capacity=1, sensor=sensor,
                         extra_plans=extra_plans, per_lane_pos=True)
        self.n_slots = n_slots
        self.clock = clock or time.monotonic
        self.queue = RequestQueue(queue_capacity, clock=self.clock)
        # completed Results land here until the caller consumes them with
        # take_finished() — long-running submit()/stream() users must drain
        # it, or host memory grows with every retired request
        self.finished: dict[int, Result] = {}
        # B=1 scratch the admission prefill runs through (donated each
        # admission, so it is ONE buffer for the life of the engine).
        # The jit zeroes it in place first — rwkv/mamba prefill consumes
        # the cache as its initial state, so a previous occupant's state
        # must not leak into the next prompt — then samples the prompt's
        # first greedy token, all in one dispatch.
        scratch_abs, _ = split(jax.eval_shape(
            lambda: model.init_cache(1, max_seq)))
        self._scratch_pool = StatePool(scratch_abs, capacity=1)
        self._scratch = self._scratch_pool.checkout()

        def prefill_sample(p, c, b):
            c = jax.tree.map(lambda a: a * 0, c)
            logits, c = steps_lib.prefill_step(self.cfg, p, c, b)
            return steps_lib.greedy_sample(logits)[..., 0], c

        # pre-create the serving instruments so metrics snapshots (and the
        # end-of-stream serve/metrics trace event) always carry the full
        # schema, zero-valued counters included
        for name in ("serving/ticks", "serving/tokens", "serving/retired",
                     "serving/deadline_miss"):
            self.metrics.counter(name)
        self.metrics.histogram("serving/ttft_s")
        self.metrics.histogram("serving/tbt_s")

        self._prefill_sample = jax.jit(prefill_sample, donate_argnums=(1,))
        self.manager = SlotManager(
            self.pool.checkout(), n_slots,
            token_tail=((self.cfg.n_codebooks,) if self.cfg.n_codebooks
                        else ()),
            clock=self.clock)

    def _decode_plans(self, extra: dict[str, Callable]
                      ) -> dict[str, Callable]:
        # every plan is wrapped with the active-mask select (free/finished
        # lanes keep their state untouched) AND greedy sampling, so one
        # dispatch per tick yields (sampled tokens, cache) directly
        def masked(fn=None):
            def plan(p, c, b):
                step = None if fn is None else (
                    lambda _cfg, p_, c_, b_: fn(p_, c_, b_))
                logits, cache = steps_lib.masked_decode_step(
                    self.cfg, p, c, b, step_fn=step)
                return steps_lib.greedy_sample(logits), cache
            return plan

        plans = {"decode/base": masked()}
        plans.update({n: masked(fn) for n, fn in extra.items()})
        return plans

    # ------------------------------------------------------------------
    def _validate(self, req: Request) -> None:
        """Reject requests that cannot fit their lane BEFORE they queue —
        decode writes token ``i`` at position prompt_len + i, and an
        out-of-range lane scatter would be silently dropped, not clamped."""
        if req.max_new_tokens <= 0:
            return                        # completes without touching a lane
        s = np.asarray(req.prompt).shape[-1]
        if not 0 < s <= self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt length {s} outside (0, "
                f"{self.max_seq}]")
        if s + req.max_new_tokens - 1 > self.max_seq:
            raise ValueError(
                f"request {req.uid}: prompt {s} + max_new_tokens "
                f"{req.max_new_tokens} - 1 exceeds max_seq {self.max_seq}")

    def submit(self, req: Request) -> None:
        """Queue one request; raises QueueFull (backpressure) when bounded
        queue capacity is reached, ValueError when it cannot fit a lane."""
        self._validate(req)
        self.queue.submit(req)

    def _admit_one(self, index: int, req: Request) -> TokenEvent:
        prompt = np.asarray(req.prompt, np.int32)
        t0 = time.perf_counter()
        tok0, self._scratch = self._prefill_sample(
            self.params, self._scratch,
            self._prefill_batch(prompt.reshape((1,) + prompt.shape)))
        tok0 = tok0[0]                       # () or (K,), device array
        prefill_s = time.perf_counter() - t0
        tok0_np = np.asarray(tok0, np.int32)  # blocks: token host-visible
        ttft_s = time.perf_counter() - t0     # admit -> first token
        self.manager.admit(index, req, self._scratch, tok0, prefill_s,
                           ttft_s=ttft_s)
        self.metrics.histogram("serving/ttft_s").observe(ttft_s)
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/admit", uid=req.uid, slot=index,
                         prompt_len=int(prompt.shape[-1]),
                         prefill_s=prefill_s, ttft_s=ttft_s)
        return TokenEvent(req.uid, tok0_np, 0,
                          done=(req.max_new_tokens <= 1))

    def _expired_event(self, req: Request) -> TokenEvent:
        return TokenEvent(req.uid, None, 0, done=True,
                          finish_reason="deadline")

    def stream(self, requests: list[Request] | None = None
               ) -> Iterator[TokenEvent]:
        """Run the continuous-batching loop, yielding one TokenEvent per
        generated token (plus terminal events), until queue and slots
        drain.  ``requests`` are fed into the bounded queue as space frees
        — external callers use ``submit`` and get backpressure instead.

        Results are published through ``self.finished`` as slots retire.
        """
        for req in requests or []:
            self._validate(req)          # fail fast, not mid-stream
        pending = collections.deque(requests or [])
        mgr = self.manager
        metrics = self.metrics
        tick = 0
        while pending or len(self.queue) or mgr.any_occupied:
            now = self.clock()

            def refill_and_expire():
                """Top the queue up from `pending`, then drop anything whose
                deadline already passed — every pop below sees an expired-
                free queue, including mid-admission refills."""
                while pending and not self.queue.full:
                    self.queue.submit(pending.popleft())
                for req in self.queue.expire(now):
                    metrics.counter("serving/deadline_miss").inc()
                    self.finished[req.uid] = Result(
                        req.uid, mgr.empty_tokens(), 0.0, 0.0, [],
                        finish_reason="deadline")
                    yield self._expired_event(req)

            yield from refill_and_expire()
            # resident lanes past their deadline retire with what they have
            for idx in mgr.expired_indices(now):
                res = mgr.retire(idx, finish_reason="deadline")
                metrics.counter("serving/deadline_miss").inc()
                metrics.counter("serving/retired").inc()
                self.finished[res.uid] = res
                yield TokenEvent(res.uid, None, res.tokens.shape[-1],
                                 done=True, finish_reason="deadline")

            # step-granular admission into free slots
            for idx in mgr.free_indices():
                yield from refill_and_expire()
                req = self.queue.pop()
                if req is None:
                    break
                if req.max_new_tokens <= 0:
                    # zero-budget request: complete without touching a lane
                    self.finished[req.uid] = Result(
                        req.uid, mgr.empty_tokens(), 0.0, 0.0, [])
                    yield TokenEvent(req.uid, None, 0, done=True,
                                     finish_reason="length")
                    continue
                ev = self._admit_one(idx, req)
                yield ev
                if ev.done:
                    res = mgr.retire(idx)
                    metrics.counter("serving/retired").inc()
                    self.finished[res.uid] = res

            queue_depth = len(self.queue)
            occupied = sum(1 for s in mgr.slots if s.occupied)
            metrics.gauge("serving/queue_depth").set(float(queue_depth))
            metrics.gauge("serving/occupancy").set(occupied / mgr.n_slots)

            if not mgr.active_mask().any():
                if pending or len(self.queue):
                    continue   # only expiries/zero-token admissions left
                break

            # ONE fused masked decode tick across all lanes — the span
            # wraps choose + dispatch + host copy, so the per-tick
            # sched/choose event nests under serve/tick in the trace
            tracer = trace_lib.get_tracer()
            span = (tracer.span("serve/tick", tick=tick,
                                queue_depth=queue_depth, occupied=occupied)
                    if tracer.enabled else trace_lib.NULL_SPAN)
            with span:
                d = self.scheduler.choose()
                plan = self.scheduler.plans[d.plan]
                t0 = time.perf_counter()
                sampled_dev, mgr.cache = plan.fn(self.params, mgr.cache,
                                                 mgr.tick_batch())
                mgr.set_sampled(sampled_dev)
                sampled = np.asarray(sampled_dev)  # blocks; 1 copy per tick
                tick_s = time.perf_counter() - t0
                plan.observe(tick_s, d.load)
                span.set(plan=d.plan, load=d.load, tick_s=tick_s)
            metrics.counter("serving/ticks").inc()
            tick += 1

            just_active = [s.index for s in mgr.slots
                           if s.occupied and s.remaining > 0]
            done_idx = set(mgr.record(sampled, d.plan))
            metrics.counter("serving/tokens").inc(len(just_active))
            token_t = time.perf_counter()
            tbt = metrics.histogram("serving/tbt_s")
            for idx in just_active:
                s = mgr.slots[idx]
                tbt.observe(token_t - s.last_token_t)
                s.last_token_t = token_t
                yield TokenEvent(s.request.uid, np.asarray(sampled[idx],
                                                           np.int32),
                                 len(s.tokens) - 1, done=idx in done_idx)
            for idx in done_idx:
                res = mgr.retire(idx)
                metrics.counter("serving/retired").inc()
                self.finished[res.uid] = res

        # one summary record per drained stream: every counter (including
        # zero-valued deadline_miss), gauge and histogram summary
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("serve/metrics", **metrics.snapshot())

    def take_finished(self) -> dict[int, Result]:
        """Pop and return every completed Result (uid -> Result).  The
        consumption half of the streaming API: call it periodically from a
        long-running submit()/stream() loop to keep host memory bounded."""
        out, self.finished = self.finished, {}
        return out

    def serve(self, requests: list[Request],
              on_token: Callable[[TokenEvent], None] | None = None
              ) -> list[Result]:
        """Convenience wrapper: stream everything, return per-request
        Results in submission order."""
        self.finished = {}
        for ev in self.stream(requests):
            if on_token is not None:
                on_token(ev)
        done = self.take_finished()
        return [done[r.uid] for r in requests]
