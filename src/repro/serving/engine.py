"""Batched serving engine with MobiRNN-style runtime policies.

The three paper mechanisms are first-class here:
  * preallocated state pools (core/state.StatePool) — decode caches are
    checked out per batch wave and returned after; no allocation on the
    serving path, pool exhaustion = explicit backpressure;
  * load-aware dispatch (core/scheduler.Scheduler) — multiple execution
    plans (e.g. fused-kernel vs baseline decode step) are registered and the
    predicted-fastest under current load runs each wave (paper Fig 7);
  * coarse batching — requests are packed into fixed-shape waves (the
    work-unit coarsening rule applied to requests; ragged tails are padded).

The engine is modality-generic: it serves any registry.Model whose config
family is text-like (dense/moe/ssm/hybrid/vlm/audio all decode token ids).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.core.state import StatePool
from repro.models.registry import Model
from repro.partitioning import split
from repro import steps as steps_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32 (or (K,S) for audio)
    max_new_tokens: int = 16


@dataclasses.dataclass
class Result:
    uid: int
    tokens: np.ndarray
    prefill_s: float
    decode_s: float
    plan_decisions: list[str]


class Engine:
    def __init__(self, model: Model, params: Any, *, batch_size: int = 4,
                 max_seq: int = 128, pool_capacity: int = 2,
                 sensor=None, extra_plans: dict[str, Callable] | None = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq

        cache_annot = jax.eval_shape(
            lambda: model.init_cache(batch_size, max_seq))
        cache_abs, _ = split(cache_annot)
        self.pool = StatePool(cache_abs, capacity=pool_capacity)

        self._prefill = jax.jit(
            lambda p, c, b: steps_lib.prefill_step(self.cfg, p, c, b),
            donate_argnums=(1,))
        base_decode = jax.jit(
            lambda p, c, b: steps_lib.decode_step(self.cfg, p, c, b),
            donate_argnums=(1,))

        self.scheduler = Scheduler(sensor or SyntheticLoadSensor(0.0))
        self.scheduler.register(Plan("decode/base", base_decode,
                                     shared=True))
        for name, fn in (extra_plans or {}).items():
            self.scheduler.register(Plan(name, jax.jit(fn,
                                                       donate_argnums=(1,)),
                                         shared=True))

    # ------------------------------------------------------------------
    def _pad_prompts(self, reqs: list[Request]) -> tuple[np.ndarray, int]:
        lens = [r.prompt.shape[-1] for r in reqs]
        s = max(lens)
        shape = ((self.batch_size, self.cfg.n_codebooks, s)
                 if self.cfg.n_codebooks else (self.batch_size, s))
        toks = np.zeros(shape, np.int32)
        for i, r in enumerate(reqs):
            toks[i, ..., s - r.prompt.shape[-1]:] = r.prompt  # left-pad
        return toks, s

    def serve(self, requests: list[Request]) -> list[Result]:
        """Serve all requests in fixed-shape waves of `batch_size`."""
        results: list[Result] = []
        for i in range(0, len(requests), self.batch_size):
            wave = requests[i:i + self.batch_size]
            pad = self.batch_size - len(wave)
            wave_padded = wave + [wave[-1]] * pad
            results.extend(self._serve_wave(wave_padded)[: len(wave)])
        return results

    def _serve_wave(self, reqs: list[Request]) -> list[Result]:
        cache = self.pool.checkout()
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype)
                             if not hasattr(s, "addressable_data") else s,
                             cache)
        toks, s0 = self._pad_prompts(reqs)
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.n_vis_tokens:
            batch["vis_embeds"] = jnp.zeros(
                (self.batch_size, self.cfg.n_vis_tokens, self.cfg.vis_dim),
                jnp.dtype(self.cfg.dtype))

        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(
            self._prefill(self.params, cache, batch))
        t_prefill = time.perf_counter() - t0

        max_new = max(r.max_new_tokens for r in reqs)
        outs = []
        decisions = []
        # prefill logits keep a singleton seq axis before the vocab dim
        tok = steps_lib.greedy_sample(logits)[..., 0]
        t0 = time.perf_counter()
        for _ in range(max_new):
            outs.append(np.asarray(tok))
            d = self.scheduler.choose()
            decisions.append(d.plan)
            plan = self.scheduler.plans[d.plan]
            t1 = time.perf_counter()
            logits, cache = jax.block_until_ready(
                plan.fn(self.params, cache, {"tokens": tok}))
            plan.observe(time.perf_counter() - t1, d.load)
            tok = steps_lib.greedy_sample(logits)
        t_decode = time.perf_counter() - t0
        self.pool.give_back(cache)

        gen = np.stack(outs, axis=-1)          # (B, [K,] max_new)
        return [Result(r.uid, gen[j], t_prefill, t_decode, decisions)
                for j, r in enumerate(reqs)]
