"""Deterministic, seeded fault injection for the serving stack.

MobiRNN's serving claim is about the MESSY device — contention, throttling,
load spikes — so the failure path needs the same engineering discipline as
the fast path, and above all it needs to be *reproducible*: a chaos run
that cannot be replayed cannot be debugged or asserted on.  This module is
the host half of that story:

* a ``FaultPlan`` is a frozen, seeded schedule of faults — NaN-poisoned
  decode lanes, failed prefills, artificially slow ticks, queue floods —
  generated once (``FaultPlan.seeded``) and serialisable
  (``save``/``to_json``) so CI uploads the exact schedule next to the trace
  it produced;
* a ``FaultInjector`` is the engine-facing view: cheap host-side lookups
  the ``SlotEngine`` consults at its injection points (tick start, prefill,
  watchdog).  The *device* half of poison injection lives in
  steps.guarded_decode_step — the injector only decides WHICH lanes, the
  NaN overwrite and the per-lane finite guard run inside the tick's jit.

Faults compose with the serving invariants, not against them: lanes never
interact, so a poisoned lane perturbs exactly one request; quarantine
resets that lane through the existing donated jit, so
``StatePool.stats.buffers_built`` stays at capacity through any schedule;
and an all-False poison mask is a bit-exact no-op, so healthy lanes'
greedy tokens are identical to a fault-free run (asserted by
tests/test_serving_faults.py and ``benchmarks/run.py --chaos-smoke``).
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.serving.slots import Request


class InjectedFault(RuntimeError):
    """Raised at a scheduled prefill-fault point, BEFORE the prefill
    dispatch (so the donated scratch cache is never consumed by a failed
    call).  The engine's admission path catches it — retry with backoff or
    terminal ``finish_reason`` — exactly as it would a real exception."""


@dataclasses.dataclass(frozen=True)
class LanePoison:
    """NaN-poison lane ``lane``'s decode output at decode tick ``tick``
    (a no-op if the lane is free then — the guard ignores inactive lanes)."""
    tick: int
    lane: int


@dataclasses.dataclass(frozen=True)
class PrefillFault:
    """Fail one prefill ATTEMPT for ``uid``.  One-shot and per-attempt:
    a retry succeeds unless another PrefillFault for the same uid remains.

    ``chunk`` refines WHERE in a chunked admission the attempt fails:
    ``None`` (default, and the whole-prompt path's only meaning) fires at
    the next attempt whatever its chunk index; ``chunk=k`` fires at the
    attempt that would run chunk ``k``, i.e. after ``k`` chunks of scratch
    state have been filled.  Either way the fault raises BEFORE dispatch,
    the lane's partial prefill state is discarded (donated zeroing reset),
    and a retry restarts from chunk 0 — token-identical to an unfaulted
    admission (asserted by tests/test_serving_faults.py)."""
    uid: int
    chunk: int | None = None


@dataclasses.dataclass(frozen=True)
class SlowTick:
    """Add ``extra_s`` seconds to the watchdog-visible latency of decode
    tick ``tick``.  Deterministic contention: no real sleep — the extra
    latency is folded into the observed tick time (and the plan's EMA), so
    chaos runs replay identically on any host."""
    tick: int
    extra_s: float


@dataclasses.dataclass(frozen=True)
class QueueFlood:
    """Submit ``n`` synthetic deadline'd requests just before decode tick
    ``tick`` — dead weight competing with real work for bounded queue
    space, exercising backpressure (QueueFull), expiry, and the
    degradation ladder's shed sweep."""
    tick: int
    n: int
    prompt_len: int = 4
    max_new_tokens: int = 4
    deadline_in_s: float = 1000.0


FAULT_KINDS = {c.__name__: c for c in
               (LanePoison, PrefillFault, SlowTick, QueueFlood)}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, replayable fault schedule.  Equality is structural, so
    ``FaultPlan.seeded(s, ...) == FaultPlan.seeded(s, ...)`` — the
    determinism contract chaos tests assert on."""
    seed: int
    faults: tuple = ()

    @classmethod
    def seeded(cls, seed: int, *, n_slots: int, ticks: int = 16,
               uids: tuple[int, ...] = (), n_poison: int = 1,
               n_prefill: int = 1, n_slow_burst: int = 1,
               burst_len: int = 3, slow_extra_s: float = 1e6,
               n_flood: int = 0, flood_n: int = 2,
               flood_deadline_s: float = 1000.0) -> "FaultPlan":
        """Generate a random-but-deterministic schedule from ``seed``:
        ``n_poison`` lane poisons over the first ``ticks`` decode ticks,
        ``n_prefill`` one-shot prefill faults drawn from ``uids``,
        ``n_slow_burst`` bursts of ``burst_len`` consecutive slow ticks,
        and ``n_flood`` queue floods of ``flood_n`` requests each."""
        rng = np.random.default_rng(seed)
        faults: list = []
        for _ in range(n_poison):
            faults.append(LanePoison(int(rng.integers(0, ticks)),
                                     int(rng.integers(0, n_slots))))
        if uids and n_prefill:
            picks = rng.choice(np.asarray(uids),
                               size=min(n_prefill, len(uids)), replace=False)
            faults.extend(PrefillFault(int(u)) for u in picks)
        for _ in range(n_slow_burst):
            t0 = int(rng.integers(0, ticks))
            faults.extend(SlowTick(t0 + k, float(slow_extra_s))
                          for k in range(burst_len))
        for _ in range(n_flood):
            faults.append(QueueFlood(int(rng.integers(0, ticks)),
                                     int(flood_n),
                                     deadline_in_s=float(flood_deadline_s)))
        return cls(seed=seed, faults=tuple(faults))

    # -- serialisation (the CI chaos-smoke artifact) --------------------
    def to_json(self) -> dict:
        return {"seed": self.seed,
                "faults": [{"kind": type(f).__name__,
                            **dataclasses.asdict(f)}
                           for f in self.faults]}

    @classmethod
    def from_json(cls, obj: dict) -> "FaultPlan":
        faults = tuple(FAULT_KINDS[f["kind"]](
            **{k: v for k, v in f.items() if k != "kind"})
            for f in obj["faults"])
        return cls(seed=obj["seed"], faults=faults)

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh, indent=1)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as fh:
            return cls.from_json(json.load(fh))


class FaultInjector:
    """Engine-facing index over a FaultPlan: O(1) host lookups per tick.

    Out-of-range faults are dropped at construction (a poison aimed past
    ``n_slots`` cannot land), and every flood request is clamped to fit a
    lane (``max_seq``) so injection never trips the engine's own
    admission validation.
    """

    #: flood uids count down from here — disjoint from client uid spaces
    FLOOD_UID_BASE = -1000

    def __init__(self, plan: FaultPlan, n_slots: int, *, vocab: int,
                 max_seq: int, token_tail: tuple[int, ...] = ()):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self._poison: dict[int, set[int]] = {}
        self._slow: dict[int, float] = {}
        self._floods: dict[int, list[QueueFlood]] = {}
        self._prefill: dict[int, list[int | None]] = {}  # uid -> chunks
        self._vocab = vocab
        self._max_seq = max_seq
        self._token_tail = token_tail
        self._next_flood_uid = self.FLOOD_UID_BASE
        for f in plan.faults:
            if isinstance(f, LanePoison):
                if 0 <= f.lane < n_slots:
                    self._poison.setdefault(f.tick, set()).add(f.lane)
            elif isinstance(f, SlowTick):
                self._slow[f.tick] = self._slow.get(f.tick, 0.0) + f.extra_s
            elif isinstance(f, PrefillFault):
                self._prefill.setdefault(f.uid, []).append(f.chunk)
            elif isinstance(f, QueueFlood):
                self._floods.setdefault(f.tick, []).append(f)

    def poison_lanes(self, tick: int) -> tuple[int, ...]:
        """Lanes whose decode output is NaN-poisoned at this tick."""
        return tuple(sorted(self._poison.get(tick, ())))

    def slow_s(self, tick: int) -> float:
        """Injected extra latency folded into this tick's observed time."""
        return self._slow.get(tick, 0.0)

    def take_prefill_fault(self, uid: int, chunk: int = 0) -> bool:
        """True exactly once per scheduled PrefillFault for ``uid`` —
        consumed per ATTEMPT, not per request.  ``chunk`` is the chunk
        index this attempt would run (0 for the whole-prompt path, which
        has exactly one attempt per admission); a scheduled fault with
        ``chunk=None`` matches any attempt, ``chunk=k`` only the k-th."""
        scheduled = self._prefill.get(uid)
        if not scheduled:
            return False
        for i, want in enumerate(scheduled):
            if want is None or want == chunk:
                scheduled.pop(i)
                return True
        return False

    def flood_requests(self, tick: int, now: float) -> list[Request]:
        """Build (and consume) this tick's synthetic flood requests."""
        specs = self._floods.pop(tick, None)
        if not specs:
            return []
        out: list[Request] = []
        for spec in specs:
            s = max(1, min(spec.prompt_len, self._max_seq))
            new = max(1, min(spec.max_new_tokens, self._max_seq - s + 1))
            for _ in range(spec.n):
                self._next_flood_uid -= 1
                prompt = self._rng.integers(
                    0, self._vocab,
                    self._token_tail + (s,)).astype(np.int32)
                out.append(Request(self._next_flood_uid, prompt,
                                   max_new_tokens=new,
                                   deadline_s=now + spec.deadline_in_s))
        return out
