"""Checkpointing: flat-path npz snapshots with atomic rename.

No orbax offline — this is a small, dependency-free implementation: pytrees
are flattened to `path/to/leaf` keys, saved with np.savez, restored against
a structural template (shape/dtype checked leaf by leaf).
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(p)) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: Any, extra: dict | None = None
         ) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_ckpt_{step}.npz")
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, template: Any, step: int | None = None) -> Any:
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves_with_path:
        key = "/".join(re.sub(r"[\[\]'\.]", "", str(e)) for e in p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
