"""AdamW with decoupled weight decay and fp32 moments (built from scratch —
no optax in this environment).  Moments are fp32 regardless of param dtype;
the update is computed in fp32 and cast back."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0

    def init(self, params: Any) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: Any, state: dict, params: Any
               ) -> tuple[Any, dict, dict]:
        """Returns (new_params, new_state, metrics)."""
        step = state["step"] + 1
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(g32)))
        if self.grad_clip:
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state["mu"], g32)
        nu = jax.tree.map(lambda n, g: self.b2 * n + (1 - self.b2) * g * g,
                          state["nu"], g32)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, n):
            u = (m / bc1) / (jnp.sqrt(n / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {
            "grad_norm": gnorm, "lr": lr}


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn
