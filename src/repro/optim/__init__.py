from repro.optim.adamw import AdamW, warmup_cosine

__all__ = ["AdamW", "warmup_cosine"]
