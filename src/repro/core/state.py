"""Preallocated, reusable recurrent-state pools (paper §3.2).

MobiRNN preallocates the (c, h) tensors once (their shapes are static given
the model) and reuses them as cells retire, bounding live memory to
2 x wavefront-width buffers.  The JAX realisation has three parts:

1. ``StatePool`` — an allocation-free checkout/return pool over preallocated
   buffers, used by the serving engine for per-request decode state (KV
   caches, SSM states, LSTM (c,h)).  Checkout NEVER allocates once the pool
   is built; exhaustion raises (backpressure), exactly the bound the paper
   enforces.  ``give_back`` resets through a donated jit, so the returned
   buffer is zeroed IN PLACE — ``stats.buffers_built`` stays at ``capacity``
   for the life of the pool (asserted by tests/test_scheduler_state.py).
2. ``donate`` — jit wrappers with ``donate_argnums`` on state arguments so
   XLA writes updated caches in place (no copy per decode step).
3. Lane-granular helpers (``lane_write`` / ``lane_zero``) — slot-resident
   continuous batching (serving/slots.py) treats one batch axis of a
   pooled buffer as B independent lanes; retirement resets JUST that lane
   through a donated jit instead of returning the whole buffer to the
   pool.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def make_buffer(spec_tree: Any) -> Any:
    """Materialise a pytree of zeros from ShapeDtypeStructs."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec_tree)


@dataclasses.dataclass
class PoolStats:
    capacity: int = 0
    outstanding: int = 0
    high_water: int = 0
    checkouts: int = 0
    resets: int = 0
    buffers_built: int = 0        # must stay == capacity after __init__
    allocation_bytes: int = 0


class StatePool:
    """Fixed-capacity pool of identically-shaped state pytrees."""

    def __init__(self, spec_tree: Any, capacity: int):
        self._spec = spec_tree
        self._free: list[Any] = []
        self.stats = PoolStats(capacity=capacity)
        for _ in range(capacity):
            self._free.append(make_buffer(spec_tree))
            self.stats.buffers_built += 1
        per_buf = int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
                          for s in jax.tree.leaves(spec_tree)))
        self.stats.allocation_bytes = per_buf * capacity
        # donated zeroing: XLA reuses the returned buffer's memory, so a
        # give_back never grows the live-buffer population.  ``a * 0``
        # (not zeros_like) keeps the input live in the computation —
        # a pure-constant output would be DCE'd past the donation and
        # freshly allocated instead of aliased in place.
        self._reset = jax.jit(
            lambda b: jax.tree.map(lambda a: a * 0, b), donate_argnums=0)

    def checkout(self) -> Any:
        if not self._free:
            raise RuntimeError(
                f"StatePool exhausted (capacity={self.stats.capacity}); "
                "MobiRNN-style preallocation bounds concurrency — release a "
                "buffer or size the pool to the wavefront width.")
        buf = self._free.pop()
        self.stats.outstanding += 1
        self.stats.checkouts += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.stats.outstanding)
        return buf

    def give_back(self, buf: Any) -> None:
        # reset without allocating fresh storage: donation in the reset jit
        self._free.append(self._reset(buf))
        self.stats.resets += 1
        self.stats.outstanding -= 1


def donate(fn: Callable, state_argnums: tuple[int, ...], **jit_kwargs):
    """jit with the state arguments donated — in-place cache updates."""
    return jax.jit(fn, donate_argnums=state_argnums, **jit_kwargs)


# ---------------------------------------------------------------------------
# Lane-granular state ops (slot-resident continuous batching)
# ---------------------------------------------------------------------------
def lane_write(tree: Any, lane: Any, index: jax.Array, axis: int) -> Any:
    """Write a width-1 ``lane`` slice into position ``index`` of ``axis``
    on every leaf.  ``lane`` leaves must already carry the singleton axis
    (e.g. a B=1 prefill cache scattered into lane i of a B-lane buffer)."""
    return jax.tree.map(
        lambda big, small: jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), index, axis=axis),
        tree, lane)


def lane_zero(tree: Any, index: jax.Array, axis: int) -> Any:
    """Zero one lane of every leaf (slot retirement) — the slot-granular
    analogue of ``StatePool.give_back``'s whole-buffer reset; callers wrap
    it in a donated jit (see ``donate`` / SlotManager) so only that lane is
    rewritten, with no ``b * 0`` reallocation of the full pool buffer."""
    return jax.tree.map(
        lambda big: jax.lax.dynamic_update_slice_in_dim(
            big, jnp.zeros_like(
                jax.lax.dynamic_slice_in_dim(big, index, 1, axis=axis)),
            index, axis=axis),
        tree)
