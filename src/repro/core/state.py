"""Preallocated, reusable recurrent-state pools (paper §3.2).

MobiRNN preallocates the (c, h) tensors once (their shapes are static given
the model) and reuses them as cells retire, bounding live memory to
2 x wavefront-width buffers.  The JAX realisation has two parts:

1. ``StatePool`` — an allocation-free checkout/return pool over preallocated
   buffers, used by the serving engine for per-request decode state (KV
   caches, SSM states, LSTM (c,h)).  Checkout NEVER allocates once the pool
   is built; exhaustion raises (backpressure), exactly the bound the paper
   enforces.
2. ``donate`` — jit wrappers with ``donate_argnums`` on state arguments so
   XLA writes updated caches in place (no copy per decode step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def make_buffer(spec_tree: Any) -> Any:
    """Materialise a pytree of zeros from ShapeDtypeStructs."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec_tree)


@dataclasses.dataclass
class PoolStats:
    capacity: int = 0
    outstanding: int = 0
    high_water: int = 0
    checkouts: int = 0
    allocation_bytes: int = 0


class StatePool:
    """Fixed-capacity pool of identically-shaped state pytrees."""

    def __init__(self, spec_tree: Any, capacity: int):
        self._spec = spec_tree
        self._free: list[Any] = [make_buffer(spec_tree) for _ in range(capacity)]
        per_buf = int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize
                          for s in jax.tree.leaves(spec_tree)))
        self.stats = PoolStats(capacity=capacity,
                               allocation_bytes=per_buf * capacity)

    def checkout(self) -> Any:
        if not self._free:
            raise RuntimeError(
                f"StatePool exhausted (capacity={self.stats.capacity}); "
                "MobiRNN-style preallocation bounds concurrency — release a "
                "buffer or size the pool to the wavefront width.")
        buf = self._free.pop()
        self.stats.outstanding += 1
        self.stats.checkouts += 1
        self.stats.high_water = max(self.stats.high_water,
                                    self.stats.outstanding)
        return buf

    def give_back(self, buf: Any) -> None:
        # reset without allocating fresh storage: donation in the reset jit
        self._free.append(jax.tree.map(lambda b: b * 0, buf))
        self.stats.outstanding -= 1


def donate(fn: Callable, state_argnums: tuple[int, ...], **jit_kwargs):
    """jit with the state arguments donated — in-place cache updates."""
    return jax.jit(fn, donate_argnums=state_argnums, **jit_kwargs)
