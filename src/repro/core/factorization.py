"""Work-unit factorization cost model (paper §3.1-3.2, Figs 2-3).

MobiRNN's central observation: the latency of a decomposed computation is

    T(n_units) = ceil(n_units / cores) * (dispatch_overhead + unit_compute)

and on a constrained accelerator (few cores, shared memory, high per-unit
overhead) the fine-grained desktop factorization (one work unit per weight
column) is dominated by the overhead term.  The same curve governs TPU
kernels: a Pallas grid with tiny blocks pays per-grid-step pipeline overhead
and underutilises the 128x128 MXU, so ``choose_block`` picks the COARSEST
block whose working set fits VMEM — the direct analogue of Fig 2c.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    cores: int                    # parallel work-unit slots
    enqueue_overhead_s: float     # PER-WORK-UNIT driver/scheduling cost
    flops_per_core: float         # sustained FLOP/s per slot
    fast_mem_bytes: int           # shared/VMEM working-set budget
    mem_bw: float                 # bytes/s to backing memory (shared)


# Calibrated so the model reproduces the paper's measured RATIOS on the
# Nexus-5-class device (Fig 3: fine-grained GPU ~4x slower than 1-thread
# CPU; Fig 4: packed GPU ~3.9x faster; Fig 6: 4-thread CPU >= 70% of GPU)
# while keeping physically plausible magnitudes (Adreno 330 ~ 130 GFLOPs
# peak but tiny shared memory and ~us-scale per-unit dispatch; Krait CPU
# ~2 GFLOPs/core sustained on this workload).
DESKTOP_GPU = DeviceProfile("desktop-gpu", 2048, 5e-9, 5e9, 96 * 1024, 300e9)
MOBILE_GPU = DeviceProfile("mobile-gpu", 128, 5e-7, 2.2e9, 8 * 1024, 12.8e9)
MOBILE_CPU4 = DeviceProfile("mobile-cpu-4t", 4, 1e-7, 0.55e9, 1 << 20,
                            12.8e9)
# single-thread CPU baseline is the paper's plain-Java loop (~0.6 GFLOP/s
# sustained on Krait for this access pattern)
MOBILE_CPU1 = DeviceProfile("mobile-cpu-1t", 1, 5e-8, 0.6e9, 1 << 20,
                            12.8e9)
TPU_V5E = DeviceProfile("tpu-v5e", 1, 1e-6, 197e12, 128 << 20, 819e9)


def unit_time(dev: DeviceProfile, n_units: int, flops_per_unit: float,
              bytes_per_unit: float = 0.0) -> float:
    """Latency of n_units work units under the paper's scheduling model:
    every unit pays an enqueue cost (serialised through the driver — this is
    what buries the fine factorization, §3.1), then units execute in waves
    of `cores`, each wave bounded by compute or its share of memory bw."""
    waves = math.ceil(n_units / dev.cores)
    compute = flops_per_unit / dev.flops_per_core
    per_core_bw = dev.mem_bw / min(n_units, dev.cores)
    mem = bytes_per_unit / per_core_bw
    return n_units * dev.enqueue_overhead_s + waves * max(compute, mem)


def factorize_gate(dev: DeviceProfile, in_dim: int, out_dim: int,
                   cols_per_unit: int, bytes_per_elem: int = 4) -> float:
    """Latency of one gate matvec (in_dim -> out_dim) split into column
    blocks of ``cols_per_unit`` (Fig 2b: cols_per_unit=1; Fig 2c: packed)."""
    n_units = math.ceil(out_dim / cols_per_unit)
    flops = 2.0 * in_dim * cols_per_unit
    byts = bytes_per_elem * (in_dim * cols_per_unit + in_dim + cols_per_unit)
    return unit_time(dev, n_units, flops, byts)


def best_cols_per_unit(dev: DeviceProfile, in_dim: int, out_dim: int,
                       bytes_per_elem: int = 4) -> int:
    """Coarsest column block whose working set fits the fast memory —
    MobiRNN's packing rule."""
    best, best_t = 1, float("inf")
    c = 1
    while c <= out_dim:
        ws = bytes_per_elem * (in_dim * c + in_dim + c)
        if ws <= dev.fast_mem_bytes:
            t = factorize_gate(dev, in_dim, out_dim, c, bytes_per_elem)
            if t < best_t:
                best, best_t = c, t
        c *= 2
    return best


# ---------------------------------------------------------------------------
# Pallas BlockSpec chooser — the TPU instantiation of the same rule.
# ---------------------------------------------------------------------------
MXU_ALIGN = 128
DEFAULT_VMEM_BUDGET = 96 << 20   # leave headroom below the 128MB v5e VMEM
#: Mobile-class fast-memory budget — the constrained tier MobiRNN targets.
#: Small enough that the seed config's whole-T-resident fused-LSTM working
#: set falls off it by T=512 (bwd) / T=2048 (fwd), so it is the shared
#: stress budget for the time-streaming pipeline: benchmarks/run.py
#: (STREAM_BUDGET rows + --stream-smoke / --quant-smoke, the CI
#: invocations) and the acceptance tests (test_plan_equivalence,
#: test_scheduler_state) all reference THIS constant so they assert one
#: viability surface.  Against it the int8-weight plan (fused_seq_q8,
#: Q8_WEIGHT_BYTES per weight instead of 4) keeps whole-T residency deeper
#: into T and lowers the (bm=1, tc=1) viability floors — the widened
#: decision table kernels/lstm_seq.choose_batch_block(quantized=True)
#: searches and the quant_* benchmark rows record.
MOBILE_VMEM_BUDGET = 320 << 10
#: Bytes per weight of the int8-quantized fused-LSTM plan (per-output-
#: channel symmetric int8, kernels/ref.quantize_q8) — the 4x lever on the
#: budget table's dominant (L, P+H, 4H) weight term.
Q8_WEIGHT_BYTES = 1


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def choose_block(m: int, n: int, k: int, bytes_per_elem: int = 2,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET,
                 align: int = MXU_ALIGN) -> tuple[int, int, int]:
    """Pick (bm, bn, bk) for an (m,k)x(k,n) matmul kernel: MXU-aligned,
    as coarse as fits `vmem_budget` for (A-block + B-block + out-block).

    Mirrors MobiRNN Fig 2c: prefer FEW LARGE grid steps over many small ones;
    shrink the grid only when the working set no longer fits fast memory.

    The sequence-resident LSTM kernels extend this rule along a second
    axis: kernels/lstm_seq.choose_batch_block seeds its batch tile from
    this function's ``bm`` and then searches the joint ``(block_b,
    time_chunk)`` surface — whole-T VMEM residency first, double-buffered
    time streaming second, smaller batch tiles last — so coarseness is
    preserved in the same priority order.
    """
    bm = min(round_up(m, align), 512)
    bn = min(round_up(n, align), 512)
    bk = min(round_up(k, align), 2048)

    def ws(bm, bn, bk):
        return bytes_per_elem * (bm * bk + bk * bn) + 4 * bm * bn

    # shrink the largest dim first until the working set fits
    while ws(bm, bn, bk) > vmem_budget:
        if bk >= max(bm, bn) and bk > align:
            bk //= 2
        elif bn >= bm and bn > align:
            bn //= 2
        elif bm > align:
            bm //= 2
        else:
            break
    return bm, bn, bk


def grid_steps(m: int, n: int, k: int, block: tuple[int, int, int]) -> int:
    bm, bn, bk = block
    return math.ceil(m / bm) * math.ceil(n / bn) * math.ceil(k / bk)
