"""Family-generic tiling substrate: one (batch_tile, time_chunk) layer.

MobiRNN's tuning loop — pick the COARSEST work unit whose working set fits
fast memory, stream what does not fit, shrink the work unit only as a last
resort — is a property of the recurrence SHAPE, not of any one family.
This module owns the three pieces every registered family shares, so the
LSTM, RWKV6 and Mamba budget tables are one code path, not three:

* the **working-set-term algebra**: a named-term accumulator
  (``WorkingSet``) plus the residency helpers every term table is built
  from — ``weight_dtype_bytes`` (the ``quantized=`` / ``w_dtype_bytes=``
  parameterisation), ``streamed_rows`` (whole-axis residency vs
  ``STREAM_SLOTS`` double-buffered chunk windows), ``bwd_window_rows``
  (the one-row trajectory overlap of reverse sweeps) and
  ``streamed_axis_rows`` (total rows a streamed axis actually moves,
  clamped/padded tail re-reads included — the HBM-traffic side of the
  same decision, used by the ``analysis`` stream-cost rooflines);
* the **fwd/bwd mode split**: ``check_mode`` validates the two-phase
  contract — ``mode="bwd"`` sizes the reverse-sweep dispatch, which
  strictly dominates the trajectory-emitting forward that feeds it
  (~3x at the paper shapes), so one number gates both training
  dispatches;
* the **coarseness-ordered joint search** (``joint_search``): whole-axis
  residency at the coarsest batch tile first, then streamed time chunks
  from coarse to fine, then smaller batch tiles — the exact priority
  order of kernels/lstm_seq.choose_batch_block, now family-generic.
  ``kernels/lstm_seq.choose_batch_block`` (-> ``lstm.plan_viability``),
  ``kernels/wkv6.choose_blocks`` (-> ``plans.rwkv_viability``) and
  ``kernels/mamba_scan.choose_blocks`` (-> ``plans.mamba_viability``)
  are all thin ``fits`` closures over this one search.

ROADMAP §Tiling substrate holds the terms-x-family decision table.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Protocol, runtime_checkable

#: Streamed axes are double-buffered: one window computes while the next
#: prefetches (kernels/lstm_seq._x_chunk_dma and the wkv6/mamba analogues).
STREAM_SLOTS = 2


@runtime_checkable
class TilePlan(Protocol):
    """The ONE interface every family's tiling result presents.

    ``joint_search`` returns a raw ``(batch_tile, time_chunk)`` pair; each
    family wraps it in its own NamedTuple with family-flavoured field names
    (``SeqBlocks.block_b``, ``WkvBlocks.bh_tile``, ``MambaBlocks.block_b``).
    Family-generic consumers — the ``plans.py`` viability factories, the
    analysis rooflines, anything that only needs "how coarse is the batch
    axis, how is time streamed" — go through these two accessors instead
    of the per-family spellings:

    * ``batch_tile`` — rows of the batch-like axis per grid step (batch
      for LSTM/Mamba, fused B*H heads for WKV6);
    * ``time_chunk`` — streamed time-window length, or None for whole-axis
      residency (the LSTM no-streaming fast path; the always-chunked
      wkv6/mamba grids never return None).
    """

    @property
    def batch_tile(self) -> int: ...

    @property
    def time_chunk(self) -> int | None: ...


def check_mode(mode: str) -> str:
    """Validate the fwd/bwd phase split shared by every family's table."""
    if mode not in ("fwd", "bwd"):
        raise ValueError(f"mode must be 'fwd' or 'bwd', got {mode!r}")
    return mode


def weight_dtype_bytes(dtype_bytes: int, w_dtype_bytes: int | None = None,
                       quantized: bool = False) -> int:
    """Bytes per weight under the shared parameterisation: explicit
    ``w_dtype_bytes`` wins; otherwise quantized plans hold int8 weights
    (1 byte) and float plans hold activation-width weights."""
    if w_dtype_bytes is not None:
        return w_dtype_bytes
    return 1 if quantized else dtype_bytes


def streamed_rows(seq_len: int, time_chunk: int | None,
                  slots: int = STREAM_SLOTS) -> int:
    """VMEM rows a (possibly streamed) sequence-axis buffer holds:
    the whole axis when ``time_chunk`` is None, else ``slots``
    double-buffered windows of ``min(time_chunk, seq_len)`` rows."""
    if time_chunk is None:
        return seq_len
    return slots * min(time_chunk, seq_len)


def bwd_window_rows(seq_len: int, time_chunk: int) -> int:
    """Rows per reverse-sweep trajectory window: chunked backward passes
    need the t-1 row of the previous chunk, so each window carries one
    overlap row whenever more than one chunk exists."""
    tc = min(time_chunk, seq_len)
    return tc + 1 if seq_len > tc else tc


def ceil_chunks(seq_len: int, time_chunk: int) -> int:
    """Grid extent of a streamed sequence axis: ceil(T / tc)."""
    tc = max(1, min(time_chunk, seq_len))
    return -(-seq_len // tc)


def streamed_axis_rows(seq_len: int, time_chunk: int | None) -> int:
    """TOTAL rows a streamed axis moves across HBM — the traffic-side twin
    of ``streamed_rows``: every chunk window is a full ``tc`` rows, so a
    non-dividing tail re-reads (clamped windows, lstm_seq) or re-moves
    (identity zero-padding, wkv6/mamba) up to ``tc - 1`` rows; pricing
    ``nc * tc`` keeps the analysis rooflines honest about that."""
    if time_chunk is None:
        return seq_len
    tc = max(1, min(time_chunk, seq_len))
    return ceil_chunks(seq_len, tc) * tc


def pad_tiles(n: int, tile: int) -> int:
    """Length of an axis zero-padded up to the tile grid (manual-DMA
    kernels address tiles themselves, so the grid must divide exactly)."""
    return ceil_chunks(n, tile) * tile


@dataclasses.dataclass
class WorkingSet:
    """Named-term working set of ONE grid step — the algebra the budget
    tables are written in.  Families ``add`` each resident block under a
    stable name (``weights``, ``x_block``, ``traj``, ...); ``bwd_only``
    terms participate only under ``mode="bwd"`` — the shared encoding of
    the ~3x fwd/bwd split.  ``total()`` is what the budget compares;
    ``terms`` is what the ROADMAP decision table and tests introspect."""
    mode: str = "fwd"
    terms: dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        check_mode(self.mode)

    def add(self, name: str, nbytes: int, bwd_only: bool = False
            ) -> "WorkingSet":
        if bwd_only and self.mode != "bwd":
            return self
        self.terms[name] = self.terms.get(name, 0) + int(nbytes)
        return self

    def total(self) -> int:
        return sum(self.terms.values())


def halving(start: int, floor: int = 1) -> Iterator[int]:
    """Coarse-to-fine halving walk: start, start//2, ..., floor."""
    c = max(floor, start)
    while True:
        yield c
        if c <= floor:
            return
        c = max(c // 2, floor)


def joint_search(batch: int, seq_len: int,
                 fits: Callable[[int, int | None], bool], *,
                 seed_batch_tile: int | None = None,
                 allow_chunk: bool = True,
                 whole_t_first: bool = True,
                 chunk_start: int | None = None
                 ) -> tuple[int, int | None] | None:
    """The coarseness-ordered joint ``(batch_tile, time_chunk)`` search.

    ``fits(batch_tile, time_chunk)`` is the family's working-set-vs-budget
    predicate (``time_chunk=None`` = whole-axis residency).  The priority
    order is MobiRNN's Fig 2c rule extended along the time axis:

    1. whole-T residency at the current batch tile (no streaming
       machinery at all) when ``whole_t_first`` and it fits;
    2. otherwise STREAM the time axis — a halving sweep from
       ``chunk_start`` (default ``seq_len // 2``) down to 1 takes the
       first, coarsest chunk that fits, keeping the batch tile coarse
       (full MXU rows, few grid steps) and hiding the window DMA behind
       compute instead of multiplying grid steps;
    3. only when even ``tc=1`` does not fit, halve the batch tile and
       retry — shrinking it also shrinks the weight-independent terms.

    Returns ``(batch_tile, time_chunk)`` — ``time_chunk=None`` only from
    step 1 — or None when even ``(1, 1)`` does not fit: the weight-class
    resident terms themselves blow the budget, and the caller routes to
    its fallback plan.  ``allow_chunk=False`` restores the pre-streaming
    surface (whole-axis residency or bust); ``whole_t_first=False`` serves
    families whose kernels always run chunked (the wkv6/mamba grids), for
    which "whole-T" is just the coarsest chunk candidate.
    """
    bm = batch if seed_batch_tile is None else seed_batch_tile
    bm = max(1, min(bm, batch))
    start = max(seq_len // 2, 1) if chunk_start is None else chunk_start
    while bm >= 1:
        if whole_t_first and fits(bm, None):
            return bm, None
        if allow_chunk:
            for tc in halving(start):
                if fits(bm, tc):
                    return bm, tc
        if bm == 1:
            break
        bm = max(bm // 2, 1)
    return None
