"""LSTM cell math — fused (MobiRNN-style) and fine-grained (desktop-CUDA-style).

The paper's §3.1/§3.2 contrast two factorizations of one gate computation:

* **CUDA-style (fine)**: the input vector is multiplied against each weight
  column as an independent work unit (120 vector products -> 120 dispatches).
  On a constrained accelerator the per-work-unit scheduling overhead dominates
  and the GPU path is ~4x SLOWER than CPU (Fig 3).
* **MobiRNN (coarse/fused)**: the four gate matmuls are combined into ONE
  matmul against W_fused in R^{(d+h) x 4h} and the point-wise gate math is
  fused behind it (Fig 2c) -> few large work units, 3.93x speedup (Fig 4).

We implement both so the benchmark suite can reproduce the Fig 3 vs Fig 4
contrast, and so tests can assert they are numerically identical.  The fused
form is also what the Pallas kernel (kernels/lstm_cell.py) implements on TPU.

Weight layout of the fused cell:  W in R^{(input_dim + hidden) x 4*hidden},
gate order (i, f, g, o) — input, forget, candidate, output.  b in R^{4*hidden}
(forget-gate bias initialised to +1.0, standard practice the paper inherits
from TensorFlow's BasicLSTMCell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.partitioning import Annot


def init_cell(key: jax.Array, input_dim: int, hidden: int,
              dtype=jnp.float32) -> dict:
    """Fused-cell parameters with logical sharding axes."""
    kw, = jax.random.split(key, 1)
    scale = (input_dim + hidden) ** -0.5
    w = jax.random.truncated_normal(
        kw, -2.0, 2.0, (input_dim + hidden, 4 * hidden), jnp.float32) * scale
    b = jnp.zeros((4 * hidden,), jnp.float32)
    # forget-gate bias = 1.0
    b = b.at[hidden:2 * hidden].set(1.0)
    return {
        "w": Annot(w.astype(dtype), ("embed", "mlp")),
        "b": Annot(b.astype(dtype), ("mlp",)),
    }


def lstm_cell_fused(params: dict, x: jax.Array, c: jax.Array, h: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """MobiRNN-style fused cell: one matmul on concat([x, h]), fused gates.

    x: (..., input_dim); c, h: (..., hidden).  Returns (c', h').
    """
    hidden = c.shape[-1]
    xh = jnp.concatenate([x, h], axis=-1)
    gates = xh @ params["w"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    del hidden
    return c_new, h_new


def lstm_cell_fine(params: dict, x: jax.Array, c: jax.Array, h: jax.Array,
                   unit_cols: int = 1) -> tuple[jax.Array, jax.Array]:
    """Desktop-CUDA-style fine-grained factorization of the same cell.

    Emulates the paper's Fig 2b: the gate computation is split into
    ``4*hidden / unit_cols`` independent column-block work units (one vector
    product per weight column when unit_cols=1), each issued as a separate
    XLA op, followed by unfused per-gate point-wise stages.  Numerically
    identical to :func:`lstm_cell_fused`; the benchmark suite measures the
    dispatch-overhead gap between the two.
    """
    hidden = c.shape[-1]
    xh = jnp.concatenate([x, h], axis=-1)
    w, b = params["w"], params["b"]
    cols = []
    for lo in range(0, 4 * hidden, unit_cols):
        hi = min(lo + unit_cols, 4 * hidden)
        # one small vector-matrix product per work unit
        cols.append(xh @ jax.lax.slice_in_dim(w, lo, hi, axis=1))
    gates = jnp.concatenate(cols, axis=-1) + b
    # unfused point-wise stages, one gate at a time (no fusion across gates)
    i = jax.nn.sigmoid(gates[..., 0 * hidden:1 * hidden])
    f = jax.nn.sigmoid(gates[..., 1 * hidden:2 * hidden])
    g = jnp.tanh(gates[..., 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(gates[..., 3 * hidden:4 * hidden])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return c_new, h_new


def cell_flops(input_dim: int, hidden: int, batch: int = 1) -> int:
    """Analytic FLOPs of one cell step (matmul-dominated)."""
    return 2 * batch * (input_dim + hidden) * 4 * hidden
