"""Family-generic execution-plan registry — MobiRNN's decision table for
EVERY recurrence family, not just the LSTM it was measured on.

The paper's levers (coarse state-resident work units, VMEM-budget-driven
tiling, the Fig 7 load-aware plan choice) are properties of the recurrence
SHAPE, so each family registers the same three things here:

* named **plans** (`PlanSpec`) — alternative executions of the same
  function, each with an **equivalence policy** (`EquivalencePolicy`):
  exact plans must match the family's oracle within per-dtype float
  tolerance; band plans (e.g. the int8-weight LSTM plan) within a
  documented error band — and, where fixed, the expected Pallas dispatch
  counts (`fwd_dispatches` / `train_dispatches`, the O(1)-in-T contract).
* a **working-set model** — the `choose_batch_block` / `choose_chunk`
  style budget function behind `Family.viability(...)`, which builds the
  `viable=` predicate the Fig 7 scheduler consumes (core/scheduler.py).
* **cases** — the family's deliberately awkward shapes.  The equivalence
  sweep in tests/test_plan_equivalence.py is GENERATED from this table
  (`value_sweep()` / `grad_sweep()`), so registering a family is all it
  takes for its plans to be swept plans x dtypes x odd-shapes x gradients.

Families registered here:

* ``lstm`` — the five plans of core/lstm.FORWARD_PLANS, unchanged (the
  registry serves them; core/lstm remains the source of truth for the plan
  functions and their names).  Viability delegates to
  ``lstm.plan_viability``.
* ``rwkv6`` — ``stepwise`` (the per-timestep oracle, models/rwkv.wkv_step
  scanned over T), ``chunked_xla`` (models/rwkv.wkv_chunked — the jnp scan
  the model shipped with, chunk clamped to the largest divisor), and
  ``chunked_scan`` (kernels/wkv6 — ONE Pallas dispatch forward, one
  reverse-sweep dispatch backward, any T).  Viability comes from
  ``kernels/wkv6.choose_chunk``.
* ``mamba`` — ``scan`` (the per-step ``lax.scan`` oracle,
  kernels/mamba_scan.mamba_scan_ref — the models/mamba recurrence) and
  ``fused_scan`` (kernels/mamba_scan.mamba_scan — ONE Pallas dispatch
  forward, one reverse-sweep dispatch backward, any T).  Viability comes
  from ``kernels/mamba_scan.choose_blocks``.

All three budget models and tile searches are thin tables over the shared
``core/tiling`` substrate — registering a family takes a working-set term
table and a ``fits`` closure, not a bespoke search.

All plan functions within a family share one calling convention;
``Family.apply`` / ``Family.grads`` run a plan and return a pytree of
arrays compared leaf-wise against the oracle's by the generated sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class EquivalencePolicy(NamedTuple):
    """How close to the family oracle a plan must stay.

    ``kind`` is "exact" (same function, float tolerance) or "band"
    (documented approximation, e.g. the int8 error band).  ``tol`` maps
    dtype name -> assert_allclose kwargs for values; ``grad_tol`` the same
    for gradients — a dtype absent from ``grad_tol`` is excluded from the
    gradient sweep (e.g. the q8 plan's gradient contract is the separate
    STE test, not oracle agreement)."""
    kind: str
    tol: dict[str, dict]
    grad_tol: dict[str, dict] | None = None


class PlanSpec(NamedTuple):
    """One named execution plan of a family."""
    name: str
    fn: Callable
    policy: EquivalencePolicy
    #: expected Pallas dispatches for one forward / one value_and_grad —
    #: None means "not fixed" (e.g. per-cell plans scale with T*L).
    fwd_dispatches: int | None = None
    train_dispatches: int | None = None


class ProfileCandidate(NamedTuple):
    """One measurable point on a family's viable tiling surface —
    what ``Family.profile_hook`` yields and obs/profile.py times.

    ``fn`` is a ready-to-call (typically jitted) callable over ``args``;
    ``point`` holds the JSON-able tiling coordinates (``block_b`` /
    ``time_chunk`` / ``chunk``); ``model_s`` is the analytic roofline
    prediction the model-vs-measured report divides against."""
    family: str
    plan: str
    point: dict
    fn: Callable
    args: tuple
    model_s: float | None = None


class Case(NamedTuple):
    """One sweep shape.  ``heavy`` cases are slow-marked in the value
    sweep; gradient sweeps additionally treat ``heavy_grad`` (and every
    non-float32 dtype) as slow — mirroring the historical quick-loop
    weighting of the LSTM sweep."""
    label: str
    shape: tuple
    heavy: bool = False
    heavy_grad: bool = True


@dataclasses.dataclass(frozen=True)
class Family:
    """A recurrence family: plans + oracle + cases + budget model."""
    name: str
    oracle: str
    plans: dict[str, PlanSpec]
    cases: tuple[Case, ...]
    dtypes: tuple[str, ...]
    #: (case, dtype) -> opaque inputs object for apply/grads
    make_inputs: Callable[[Case, str], Any]
    #: (plan_name, inputs) -> pytree of arrays (compared leaf-wise)
    apply: Callable[[str, Any], Any]
    #: (plan_name, inputs) -> pytree of gradient arrays
    grads: Callable[[str, Any], Any]
    #: family-specific keyword signature; returns the Fig 7 ``viable=``
    #: predicate (plan name -> bool) from the VMEM working-set model
    viability: Callable[..., Callable[[str], bool]]
    #: measured-profiler hook: ``(vmem_budget=..., max_points=..., **shape
    #: overrides) -> list[ProfileCandidate]`` enumerating the viable
    #: tiling surface for obs/profile.profile_families to time; None means
    #: the family opts out of measured profiling
    profile_hook: Callable[..., list] | None = None

    def comparable_plans(self) -> list[str]:
        return [n for n in self.plans if n != self.oracle]

    def tol(self, plan: str, dtype: str) -> dict:
        return self.plans[plan].policy.tol[dtype]

    def grad_tol(self, plan: str, dtype: str) -> dict | None:
        gt = self.plans[plan].policy.grad_tol
        return None if gt is None else gt.get(dtype)


FAMILIES: dict[str, Family] = {}


def register_family(family: Family) -> Family:
    if family.oracle not in family.plans:
        raise ValueError(f"oracle {family.oracle!r} not among plans "
                         f"{list(family.plans)}")
    FAMILIES[family.name] = family
    return family


def get_family(name: str) -> Family:
    return FAMILIES[name]


# ---------------------------------------------------------------------------
# Sweep generation — the single source the equivalence tests parametrize on
# ---------------------------------------------------------------------------
class SweepCase(NamedTuple):
    family: str
    plan: str
    case: Case
    dtype: str
    heavy: bool

    @property
    def id(self) -> str:
        return f"{self.family}-{self.plan}-{self.case.label}-{self.dtype}"


def value_sweep() -> list[SweepCase]:
    """plans x cases x dtypes for every registered family (oracle
    excluded — it is the reference, not a claim)."""
    out = []
    for fam in FAMILIES.values():
        for plan in fam.comparable_plans():
            for case in fam.cases:
                for dtype in fam.dtypes:
                    if dtype not in fam.plans[plan].policy.tol:
                        continue
                    out.append(SweepCase(fam.name, plan, case, dtype,
                                         heavy=case.heavy))
    return out


def grad_sweep() -> list[SweepCase]:
    """Gradient sweep: only (plan, dtype) pairs whose policy carries a
    ``grad_tol`` — the training-story guarantee, generated per family."""
    out = []
    for fam in FAMILIES.values():
        for plan in fam.comparable_plans():
            for case in fam.cases:
                for dtype in fam.dtypes:
                    if fam.grad_tol(plan, dtype) is None:
                        continue
                    heavy = case.heavy_grad or dtype != "float32"
                    out.append(SweepCase(fam.name, plan, case, dtype, heavy))
    return out


# ---------------------------------------------------------------------------
# Scheduler glue — one predicate over many families
# ---------------------------------------------------------------------------
def scheduler_viability(bindings: dict[str, tuple[str, Callable[[str], bool]]]
                        ) -> Callable[[str], bool]:
    """Combine per-family viability predicates into the single
    ``Scheduler(viable=...)`` callable.

    ``bindings`` maps a SCHEDULER plan name to ``(family_plan_name,
    family_predicate)`` — benchmarks register e.g. ``accel_seq`` for the
    lstm family's ``fused_seq`` and ``accel_wkv`` for rwkv6's
    ``chunked_scan``; names not bound to any family stay always-viable
    (CPU fallbacks)."""
    def viable(plan_name: str) -> bool:
        bound = bindings.get(plan_name)
        if bound is None:
            return True
        family_plan, predicate = bound
        return predicate(family_plan)

    return viable


# ===========================================================================
# lstm family — FORWARD_PLANS served through the registry, names unchanged
# ===========================================================================
#: per-dtype tolerance of the exact LSTM plans vs forward_sequential
LSTM_TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
            "bfloat16": dict(rtol=5e-2, atol=5e-2)}
LSTM_GRAD_TOL = {"float32": dict(rtol=2e-4, atol=2e-5),
                 "bfloat16": dict(rtol=8e-2, atol=8e-2)}
#: THE documented int8 error band (ROADMAP §Quantization): per-output-
#: channel symmetric int8 bounds each dequantized weight within
#: max|w_col|/254 of f32, and the saturating LSTM nonlinearities keep the
#: recurrence from amplifying it — logits land within 5e-2 of the f32
#: plans at the paper shapes (measured headroom ~5x).
Q8_BAND = dict(rtol=5e-2, atol=5e-2)

_LSTM_EXACT = EquivalencePolicy("exact", LSTM_TOL, LSTM_GRAD_TOL)
#: the q8 plan: banded values, and NO oracle gradient contract — its
#: training guarantee is exact-math STE agreement (test_plan_equivalence's
#: Q8 section), not closeness to the f32 oracle's gradients.
_LSTM_Q8 = EquivalencePolicy("band",
                             {d: Q8_BAND for d in ("float32",)},
                             grad_tol=None)

#: (batch, seq_len, hidden, input_dim, n_layers) — none block-aligned
_LSTM_CASES = (
    Case("b3t7h48d9l2", (3, 7, 48, 9, 2), heavy_grad=False),  # canonical
    Case("b1t5h33d9l3", (1, 5, 33, 9, 3)),    # B=1, hidden not lane-aligned
    Case("b5t3h16d40l2", (5, 3, 16, 40, 2)),  # input_dim > hidden: P padding
)


def _lstm_make_inputs(case: Case, dtype: str):
    from repro.configs.mobirnn_lstm import LSTMConfig
    from repro.core import lstm

    b, t, h, d, n_layers = case.shape
    cfg = dataclasses.replace(LSTMConfig(), hidden=h, input_dim=d,
                              n_layers=n_layers, seq_len=t, dtype=dtype)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, d), jnp.dtype(dtype))
    labels = jnp.arange(b) % cfg.n_classes
    return cfg, params, x, labels


def _lstm_apply(plan: str, inputs):
    from repro.core import lstm

    cfg, params, x, _ = inputs
    return lstm.FORWARD_PLANS[plan](params, x, cfg)


def _lstm_grads(plan: str, inputs):
    from repro.core import lstm

    cfg, params, x, labels = inputs
    _, g = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg,
                               forward=lstm.FORWARD_PLANS[plan]))(params)
    return g


def _lstm_viability(*args, **kwargs):
    from repro.core import lstm

    return lstm.plan_viability(*args, **kwargs)


def _lstm_profile_candidates(*, vmem_budget: int | None = None,
                             max_points: int = 4, batch: int = 4,
                             seq_len: int = 48) -> list[ProfileCandidate]:
    """Measured-profiler candidates: jitted ``fused_seq`` dispatches over
    a deterministic slice of the viable ``(block_b, time_chunk)`` surface
    at the canonical MobiRNN layer shape — coarsest tilings first (whole-T
    residency, full batch), then finer time chunks and batch halves, each
    admitted only if ``working_set_bytes`` fits the budget.  ``model_s``
    is the two-term roofline of ``analysis.lstm_seq_stream_costs``."""
    import functools

    from repro import analysis
    from repro.configs.mobirnn_lstm import LSTMConfig
    from repro.core import factorization as fz
    from repro.core import lstm as lstm_lib
    from repro.kernels import lstm_seq as seq_lib

    cfg = LSTMConfig()
    budget = fz.DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    p = lstm_lib._plain_params(
        lstm_lib.init_params(jax.random.PRNGKey(0), cfg))
    w, b, p_width = seq_lib.stack_params(p["layers"], cfg.hidden)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (batch, seq_len, cfg.input_dim), jnp.float32)
    xp = seq_lib.pad_input(x, p_width)

    time_chunks: list[int | None] = [None]
    for t in (seq_len // 2, seq_len // 4):
        if 1 <= t < seq_len and t not in time_chunks:
            time_chunks.append(t)
    out: list[ProfileCandidate] = []
    for bm in sorted({batch, max(1, batch // 2)}, reverse=True):
        for tc in time_chunks:
            if len(out) >= max_points:
                return out
            ws = seq_lib.working_set_bytes(
                seq_len, cfg.n_layers, p_width, cfg.hidden, bm,
                time_chunk=tc)
            if ws > budget:
                continue
            fn = jax.jit(functools.partial(
                seq_lib.lstm_seq, block_b=bm, time_chunk=tc))
            costs = analysis.lstm_seq_stream_costs(
                seq_len, cfg.n_layers, p_width, cfg.hidden, batch, bm, tc)
            out.append(ProfileCandidate(
                "lstm", "fused_seq", {"block_b": bm, "time_chunk": tc},
                fn, (w, b, xp),
                model_s=max(costs["t_compute"], costs["t_memory"])))
    return out


def _build_lstm_family() -> Family:
    from repro.core import lstm

    specs: dict[str, PlanSpec] = {}
    for name, fn in lstm.FORWARD_PLANS.items():
        if name == "fused_seq_q8":
            spec = PlanSpec(name, fn, _LSTM_Q8,
                            fwd_dispatches=1, train_dispatches=2)
        elif name in ("fused_seq",):
            spec = PlanSpec(name, fn, _LSTM_EXACT,
                            fwd_dispatches=1, train_dispatches=2)
        else:
            spec = PlanSpec(name, fn, _LSTM_EXACT)
        specs[name] = spec
    return Family(
        name="lstm", oracle="sequential", plans=specs, cases=_LSTM_CASES,
        dtypes=("float32", "bfloat16"), make_inputs=_lstm_make_inputs,
        apply=_lstm_apply, grads=_lstm_grads, viability=_lstm_viability,
        profile_hook=_lstm_profile_candidates)


# ===========================================================================
# rwkv6 family — stepwise oracle, XLA chunked scan, fused Pallas chunked scan
# ===========================================================================
#: chunked-vs-stepwise agreement band (log-space chunk math reassociates
#: the decay products; same bound tests/test_properties.py measures)
RWKV_TOL = {"float32": dict(rtol=5e-4, atol=5e-4),
            "bfloat16": dict(rtol=6e-2, atol=6e-2)}
RWKV_GRAD_TOL = {"float32": dict(rtol=2e-3, atol=2e-3)}

_RWKV_EXACT = EquivalencePolicy("exact", RWKV_TOL, RWKV_GRAD_TOL)

#: (B, T, H, dk, dv, chunk) — C=1, C=T, non-dividing T, chunk > T all on
#: the table, so the padding and clamping paths are part of the sweep
_RWKV_CASES = (
    Case("c8t24", (2, 24, 2, 8, 8, 8)),                     # C | T
    Case("c1", (2, 12, 2, 8, 8, 1), heavy_grad=False),      # C=1: per-step
    Case("cT", (1, 16, 2, 8, 8, 16)),                       # C=T: one chunk
    Case("oddT", (2, 23, 2, 8, 8, 8), heavy_grad=False),    # pad path
    Case("cgtT", (1, 7, 2, 8, 10, 32)),                     # clamp, dk != dv
    Case("long", (2, 96, 2, 16, 16, 16), heavy=True),
)


def _rwkv_make_inputs(case: Case, dtype: str):
    import zlib

    B, T, H, dk, dv, chunk = case.shape
    dt = jnp.dtype(dtype)
    seed = zlib.crc32(case.label.encode()) % (2 ** 31)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, T, H, dk), dt)
    k = jax.random.normal(ks[1], (B, T, H, dk), dt)
    v = jax.random.normal(ks[2], (B, T, H, dv), dt)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, dk)))     # f32, <= 0
    u = jax.random.normal(ks[4], (H, dk))
    state = jax.random.normal(ks[5], (B, H, dk, dv)) * 0.3       # f32
    return (r, k, v, logw, u, state), chunk


def _rwkv_stepwise(r, k, v, logw, u, state, *, chunk):
    """Per-timestep oracle: models/rwkv.wkv_step scanned over T — the
    fine-grained 'CUDA-style' plan every chunked plan must reproduce."""
    from repro.models import rwkv as rwkv_lib

    def step(s, xs):
        out, s = rwkv_lib.wkv_step(*xs, u, s)
        return s, out

    swap = lambda a: jnp.swapaxes(a, 0, 1)           # (B,T,H,*) -> (T,B,H,*)
    state, outs = jax.lax.scan(
        step, state.astype(jnp.float32), tuple(map(swap, (r, k, v, logw))))
    return swap(outs).astype(v.dtype), state


def _rwkv_chunked_xla(r, k, v, logw, u, state, *, chunk):
    """models/rwkv.wkv_chunked with the model's divisor clamp — the jnp
    lax.scan plan (O(T/C) fused-loop iterations, no Pallas)."""
    from repro.models import rwkv as rwkv_lib

    S = r.shape[1]
    c = max(1, min(chunk, S))
    while S % c:              # largest divisor of S not above the target
        c -= 1
    out, state = rwkv_lib.wkv_chunked(r, k, v, logw, u, state, c)
    return out.astype(v.dtype), state


def _rwkv_chunked_scan(r, k, v, logw, u, state, *, chunk, bh_tile=1,
                       bwd=None, interpret=True):
    """kernels/wkv6 Pallas plan: model layout (B,S,H,*) folded to the
    kernel's (B*H, S, *), u broadcast per batch-head (its VJP sums the
    cotangent back over B), any T via the kernel's identity zero-pad."""
    from repro.kernels import wkv6 as wkv6_lib

    if bwd is None:
        bwd = wkv6_lib.FUSED_BWD
    B, S, H, dk = r.shape
    dv = v.shape[-1]

    def merge(a):
        return jnp.swapaxes(a, 1, 2).reshape(B * H, S, a.shape[-1])

    ub = jnp.broadcast_to(u[None], (B, H, dk)).reshape(B * H, dk)
    out, s_out = wkv6_lib.wkv6(
        merge(r), merge(k), merge(v), merge(logw), ub,
        state.reshape(B * H, dk, dv), chunk=chunk, bh_tile=bh_tile,
        bwd=bwd, interpret=interpret)
    out = jnp.swapaxes(out.reshape(B, H, S, dv), 1, 2)
    return out, s_out.reshape(B, H, dk, dv)


RWKV_PLANS: dict[str, Callable] = {
    "stepwise": _rwkv_stepwise,
    "chunked_xla": _rwkv_chunked_xla,
    "chunked_scan": _rwkv_chunked_scan,
}


def _rwkv_apply(plan: str, inputs):
    args, chunk = inputs
    return RWKV_PLANS[plan](*args, chunk=chunk)


def _rwkv_grads(plan: str, inputs):
    (r, k, v, logw, u, state), chunk = inputs

    def loss(r, k, v, logw, u, state):
        out, s = RWKV_PLANS[plan](r, k, v, logw, u, state, chunk=chunk)
        return (jnp.sum(jnp.tanh(out.astype(jnp.float32)))
                + 0.5 * jnp.sum(s * s))

    return jax.grad(loss, argnums=(0, 1, 2, 3, 4, 5))(
        r, k, v, logw, u, state)


def _plan_viable(blocks, gated_plan_names: tuple[str, ...]
                 ) -> Callable[[str], bool]:
    """The shared viability closure every family factory returns: the
    accelerator plan(s) in ``gated_plan_names`` are real plans only when
    the family's tiling decision found a fit; every other plan name stays
    viable (the CPU-path fallbacks).  ``blocks`` must be the family's
    decision result — any ``core/tiling.TilePlan`` (SeqBlocks / WkvBlocks /
    MambaBlocks through their common ``batch_tile``/``time_chunk``
    accessors) or None; the isinstance assert is what keeps a new family
    from wiring a bespoke result type past the shared interface."""
    from repro.core import tiling

    assert blocks is None or isinstance(blocks, tiling.TilePlan), blocks

    def viable(plan_name: str) -> bool:
        return blocks is not None or plan_name not in gated_plan_names

    return viable


def rwkv_viability(seq_len: int, dk: int, dv: int, *, chunk: int = 32,
                   dtype_bytes: int = 4, vmem_budget: int | None = None,
                   train: bool = False,
                   scan_plan_names: tuple[str, ...] = ("chunked_scan",)
                   ) -> Callable[[str], bool]:
    """Fig 7 ``viable=`` predicate for the rwkv6 family, from the
    kernels/wkv6 working-set model: the Pallas plan is only a real plan
    while ``choose_blocks`` finds a chunk whose (C, C, dk) intra-chunk
    tensor plus tiles fit the budget — ``train=True`` sizes the
    reverse-sweep backward instead (~3x), exactly like the lstm family's
    ``plan_viability(train=True)``.  All other plan names stay viable
    (stepwise/chunked_xla are the CPU-path fallbacks)."""
    from repro.kernels import wkv6 as wkv6_lib

    blocks = wkv6_lib.choose_blocks(
        1, seq_len, dk, dv, target=chunk, dtype_bytes=dtype_bytes,
        vmem_budget=vmem_budget, mode="bwd" if train else "fwd")
    return _plan_viable(blocks, scan_plan_names)


def _rwkv_profile_candidates(*, vmem_budget: int | None = None,
                             max_points: int = 4, seq_len: int = 64,
                             n_bh: int = 4, dk: int = 8, dv: int = 8,
                             target: int = 16) -> list[ProfileCandidate]:
    """Measured-profiler candidates for the rwkv6 family over the widened
    ``(bh_tile, chunk)`` surface: for each bh tile on ``choose_blocks``'s
    halving walk (coarsest first), jitted ``chunked_scan`` (kernels/wkv6)
    dispatches along the halving chunk search — target C first, then C/2,
    C/4, ... — keeping only points whose working set fits the budget.
    ``model_s`` comes from ``analysis.wkv6_stream_costs``."""
    import functools

    from repro import analysis
    from repro.core import factorization as fz, tiling
    from repro.kernels import wkv6 as wkv6_lib

    budget = fz.DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = jax.random.normal(ks[0], (n_bh, seq_len, dk), jnp.float32)
    k = jax.random.normal(ks[1], (n_bh, seq_len, dk), jnp.float32)
    v = jax.random.normal(ks[2], (n_bh, seq_len, dv), jnp.float32)
    logw = -jnp.exp(jax.random.normal(ks[3], (n_bh, seq_len, dk)))
    u = jax.random.normal(ks[4], (n_bh, dk))
    state = jax.random.normal(ks[5], (n_bh, dk, dv)) * 0.3

    out: list[ProfileCandidate] = []
    per_tile = max(1, max_points // 2)   # spread points over both axes
    for bt in tiling.halving(n_bh):
        c = max(1, min(target, seq_len))
        taken = 0
        while len(out) < max_points and taken < per_tile:
            ws = wkv6_lib.working_set_bytes(seq_len, dk, dv, c,
                                            bh_tile=bt)
            if ws <= budget:
                fn = jax.jit(functools.partial(
                    wkv6_lib.wkv6, chunk=c, bh_tile=bt))
                costs = analysis.wkv6_stream_costs(
                    seq_len, n_bh, dk, dv, c, bh_tile=bt)
                out.append(ProfileCandidate(
                    "rwkv6", "chunked_scan", {"chunk": c, "bh_tile": bt},
                    fn, (r, k, v, logw, u, state),
                    model_s=max(costs["t_compute"], costs["t_memory"])))
                taken += 1
            if c == 1:
                break
            c //= 2
        if len(out) >= max_points:
            break
    return out


def _build_rwkv_family() -> Family:
    specs = {
        "stepwise": PlanSpec("stepwise", _rwkv_stepwise, _RWKV_EXACT),
        "chunked_xla": PlanSpec("chunked_xla", _rwkv_chunked_xla,
                                _RWKV_EXACT),
        "chunked_scan": PlanSpec("chunked_scan", _rwkv_chunked_scan,
                                 _RWKV_EXACT,
                                 fwd_dispatches=1, train_dispatches=2),
    }
    return Family(
        name="rwkv6", oracle="stepwise", plans=specs, cases=_RWKV_CASES,
        dtypes=("float32", "bfloat16"), make_inputs=_rwkv_make_inputs,
        apply=_rwkv_apply, grads=_rwkv_grads, viability=rwkv_viability,
        profile_hook=_rwkv_profile_candidates)


# ===========================================================================
# mamba family — lax.scan oracle, fused Pallas stepwise selective scan
# ===========================================================================
#: fused-vs-scan agreement band: both paths run the identical per-step
#: recurrence in f32, diffs come only from XLA fusion inside a step
MAMBA_TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
             "bfloat16": dict(rtol=2e-2, atol=2e-2)}
MAMBA_GRAD_TOL = {"float32": dict(rtol=2e-4, atol=2e-5)}

_MAMBA_EXACT = EquivalencePolicy("exact", MAMBA_TOL, MAMBA_GRAD_TOL)

#: (B, T, d_inner, d_state, chunk, block_b) — C=1, C=T, non-dividing T
#: (pad path) and a non-dividing batch tile (row-mask path) all on the
#: table, so every clamp/pad branch is part of the sweep
_MAMBA_CASES = (
    Case("c8t24", (2, 24, 8, 4, 8, 2)),                     # C | T, bm | B
    Case("c1", (2, 12, 8, 4, 1, 2), heavy_grad=False),      # C=1: per-step
    Case("cT", (1, 16, 8, 4, 16, 1)),                       # C=T: one chunk
    Case("oddT", (2, 23, 8, 4, 8, 2), heavy_grad=False),    # pad path
    Case("btail", (3, 16, 8, 4, 8, 2)),                     # bm does not | B
    Case("long", (2, 96, 16, 8, 16, 2), heavy=True),
)


def _mamba_make_inputs(case: Case, dtype: str):
    import zlib

    B, T, di, ds, chunk, block_b = case.shape
    dt_ = jnp.dtype(dtype)
    seed = zlib.crc32(case.label.encode()) % (2 ** 31)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (B, T, di), dt_)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, di)))   # f32, > 0
    b = jax.random.normal(ks[2], (B, T, ds))                     # f32
    c = jax.random.normal(ks[3], (B, T, ds))                     # f32
    a = -jnp.exp(jax.random.normal(ks[4], (di, ds)))             # f32, < 0
    h0 = jax.random.normal(ks[5], (B, di, ds)) * 0.3             # f32
    return (x, dt, b, c, a, h0), chunk, block_b


def _mamba_scan(x, dt, b, c, a, h0, *, chunk, block_b):
    """Per-step lax.scan oracle — the models/mamba recurrence verbatim
    (kernels/mamba_scan.mamba_scan_ref)."""
    from repro.kernels import mamba_scan as ms_lib

    return ms_lib.mamba_scan_ref(x, dt, b, c, a, h0)


def _mamba_fused_scan(x, dt, b, c, a, h0, *, chunk, block_b, bwd=None,
                      interpret=True):
    """kernels/mamba_scan Pallas plan: ONE dispatch forward over a
    (batch-tile, time-chunk) grid with the f32 state carried in VMEM
    scratch, one reverse-sweep dispatch backward, any T and B via the
    identity zero-pad (dt=0 rows neither decay nor inject)."""
    from repro.kernels import mamba_scan as ms_lib

    if bwd is None:
        bwd = ms_lib.FUSED_BWD
    return ms_lib.mamba_scan(x, dt, b, c, a, h0, chunk=chunk,
                             block_b=block_b, bwd=bwd, interpret=interpret)


MAMBA_PLANS: dict[str, Callable] = {
    "scan": _mamba_scan,
    "fused_scan": _mamba_fused_scan,
}


def _mamba_apply(plan: str, inputs):
    args, chunk, block_b = inputs
    return MAMBA_PLANS[plan](*args, chunk=chunk, block_b=block_b)


def _mamba_grads(plan: str, inputs):
    (x, dt, b, c, a, h0), chunk, block_b = inputs

    def loss(x, dt, b, c, a, h0):
        y, h = MAMBA_PLANS[plan](x, dt, b, c, a, h0, chunk=chunk,
                                 block_b=block_b)
        return (jnp.sum(jnp.tanh(y.astype(jnp.float32)))
                + 0.5 * jnp.sum(h * h))

    return jax.grad(loss, argnums=(0, 1, 2, 3, 4, 5))(
        x, dt, b, c, a, h0)


def mamba_viability(batch: int, seq_len: int, d_inner: int, d_state: int,
                    *, dtype_bytes: int = 4,
                    vmem_budget: int | None = None, train: bool = False,
                    scan_plan_names: tuple[str, ...] = ("fused_scan",)
                    ) -> Callable[[str], bool]:
    """Fig 7 ``viable=`` predicate for the mamba family, from the
    kernels/mamba_scan working-set model: the Pallas plan is only a real
    plan while ``choose_blocks`` finds a (batch-tile, time-chunk) pair
    that fits the budget — ``train=True`` sizes the reverse-sweep
    backward instead (~3x), exactly like ``rwkv_viability(train=True)``.
    The ``scan`` oracle stays viable (it is the CPU-path fallback)."""
    from repro.kernels import mamba_scan as ms_lib

    blocks = ms_lib.choose_blocks(
        batch, seq_len, d_inner, d_state, dtype_bytes=dtype_bytes,
        vmem_budget=vmem_budget, mode="bwd" if train else "fwd")
    return _plan_viable(blocks, scan_plan_names)


def _mamba_profile_candidates(*, vmem_budget: int | None = None,
                              max_points: int = 4, batch: int = 4,
                              seq_len: int = 64, d_inner: int = 16,
                              d_state: int = 8) -> list[ProfileCandidate]:
    """Measured-profiler candidates for the mamba family over the
    substrate's (block_b, time_chunk) surface: for each batch tile on the
    halving walk (coarsest first), whole-T residency first then halving
    time chunks — the exact coarseness order ``choose_blocks`` searches.
    ``model_s`` comes from ``analysis.mamba_scan_stream_costs``."""
    import functools

    from repro import analysis
    from repro.core import factorization as fz, tiling
    from repro.kernels import mamba_scan as ms_lib

    budget = fz.DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    ks = jax.random.split(jax.random.PRNGKey(3), 6)
    x = jax.random.normal(ks[0], (batch, seq_len, d_inner), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(
        ks[1], (batch, seq_len, d_inner)))
    b = jax.random.normal(ks[2], (batch, seq_len, d_state))
    c = jax.random.normal(ks[3], (batch, seq_len, d_state))
    a = -jnp.exp(jax.random.normal(ks[4], (d_inner, d_state)))
    h0 = jax.random.normal(ks[5], (batch, d_inner, d_state)) * 0.3

    out: list[ProfileCandidate] = []
    per_tile = max(1, max_points // 2)   # spread points over both axes
    for bm in tiling.halving(batch):
        taken = 0
        cn = seq_len
        while len(out) < max_points and taken < per_tile:
            ws = ms_lib.working_set_bytes(seq_len, d_inner, d_state,
                                          bm, cn)
            if ws <= budget:
                fn = jax.jit(functools.partial(
                    ms_lib.mamba_scan, chunk=cn, block_b=bm))
                costs = analysis.mamba_scan_stream_costs(
                    seq_len, batch, d_inner, d_state, bm, cn)
                out.append(ProfileCandidate(
                    "mamba", "fused_scan",
                    {"block_b": bm, "chunk": cn},
                    fn, (x, dt, b, c, a, h0),
                    model_s=max(costs["t_compute"], costs["t_memory"])))
                taken += 1
            if cn == 1:
                break
            cn //= 2
        if len(out) >= max_points:
            break
    return out


def _build_mamba_family() -> Family:
    specs = {
        "scan": PlanSpec("scan", _mamba_scan, _MAMBA_EXACT),
        "fused_scan": PlanSpec("fused_scan", _mamba_fused_scan,
                               _MAMBA_EXACT,
                               fwd_dispatches=1, train_dispatches=2),
    }
    return Family(
        name="mamba", oracle="scan", plans=specs, cases=_MAMBA_CASES,
        dtypes=("float32", "bfloat16"), make_inputs=_mamba_make_inputs,
        apply=_mamba_apply, grads=_mamba_grads, viability=mamba_viability,
        profile_hook=_mamba_profile_candidates)


register_family(_build_lstm_family())
register_family(_build_rwkv_family())
register_family(_build_mamba_family())
