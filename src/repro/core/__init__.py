"""MobiRNN core: the paper's contribution as composable JAX modules."""
from repro.core import cell, factorization, lstm, scheduler, state, wavefront

__all__ = ["cell", "factorization", "lstm", "scheduler", "state", "wavefront"]
