"""The paper's model: stacked LSTM for activity recognition (MobiRNN §4.1).

FIVE execution plans over the same parameters (the first four numerically
equivalent, asserted by tests/test_plan_equivalence.py; the fifth equivalent
within a documented int8 error band), and when the scheduler
(core/scheduler.py) should prefer each:

* ``forward_sequential`` — reference plan: scan over time, layers unrolled
  inside the step (the single-threaded baseline of Fig 3/4).  Prefer on the
  CPU path / under high accelerator load (paper Fig 7).
* ``forward_wavefront`` — the paper's Fig 1 diagonal parallelism: cells on an
  anti-diagonal (layer i, time t, i+t = const) execute together as ONE vmapped
  cell call over layers (see core/wavefront.py).  Prefer when L is large
  enough for the diagonal batching to pay for its masking overhead.
* ``forward_fused_kernel`` — sequential plan but each cell is the Pallas
  fused-gate kernel (kernels/lstm_cell.py) instead of jnp ops.  T x L kernel
  dispatches; prefer in COMPUTE-BOUND regimes where H is too large for the
  whole weight stack to sit in VMEM (the per-cell kernel tiles hidden).
* ``forward_fused_seq`` — sequence-resident Pallas kernel
  (kernels/lstm_seq.py): the whole T-step, L-layer recurrence in ONE
  dispatch, weights loaded to VMEM once, (c, h) never leaving VMEM.  Prefer
  in DISPATCH-BOUND regimes (small/medium models, long sequences) — the
  MobiRNN fast path.  Falls back to ``forward_fused_kernel`` when the
  stacked weights exceed the VMEM budget (core/factorization).
* ``forward_fused_seq_q8`` — the sequence-resident plan with per-output-
  channel symmetric INT8 weights (f32 scales + biases), dequantized on the
  fly inside the fused kernels.  Quarters the dominant VMEM term and the
  streamed weight traffic, so it stays whole-T-resident (and viable at all)
  deeper into the ``(T, budget)`` surface than ``fused_seq`` — the
  RTMobile/Grachev compression lever applied to the MobiRNN fast path.
  Matches the dequantize oracle (kernels/ref.lstm_seq_q8) within fp
  rounding and the f32 plans within the int8 error band.

All five are real TRAINING choices too: under ``jax.grad`` the fused plans
carry custom VJPs — ``fused_seq`` runs ONE reverse-sweep BPTT kernel
(kernels/lstm_seq_bwd.py; 2 dispatches per value_and_grad, O(1) in T) with
an oracle-VJP fallback gated by ``choose_batch_block(mode="bwd")``;
``fused_cell`` differentiates the per-cell oracle.  Train-time schedulers
must size the backward working set via ``plan_viability(train=True)``.

The classifier head follows Guan & Ploetz-style HAR models: last hidden state
-> dense -> 6-way softmax.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import cell as cell_lib
from repro.partitioning import Annot, split


def init_params(key: jax.Array, cfg: LSTMConfig) -> dict:
    """Annotated parameter tree for the stacked LSTM + HAR head."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    for i in range(cfg.n_layers):
        in_dim = cfg.input_dim if i == 0 else cfg.hidden
        layers.append(cell_lib.init_cell(keys[i], in_dim, cfg.hidden, dtype))
    head_w = jax.random.truncated_normal(
        keys[-1], -2.0, 2.0, (cfg.hidden, cfg.n_classes), jnp.float32
    ) * cfg.hidden ** -0.5
    return {
        "layers": layers,
        "head": {
            "w": Annot(head_w.astype(dtype), ("embed", None)),
            "b": Annot(jnp.zeros((cfg.n_classes,), dtype), (None,)),
        },
    }


def init_state(cfg: LSTMConfig, batch: int, dtype=jnp.float32
               ) -> tuple[jax.Array, jax.Array]:
    """Preallocated (c, h) buffers, one pair per layer (paper §3.2: state
    tensors are preallocated once and reused across the whole sequence)."""
    shape = (cfg.n_layers, batch, cfg.hidden)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def _plain_params(params: dict) -> dict:
    values, _ = split(params)
    return values


def forward_sequential(
    params: dict, x: jax.Array, cfg: LSTMConfig,
    cell_fn: Callable = cell_lib.lstm_cell_fused,
) -> jax.Array:
    """Reference plan.  x: (batch, seq, input_dim) -> logits (batch, classes).

    Scan over time; within a step, layers run in dependency order.  The (c,h)
    buffers are the scan carry — XLA keeps them in place (donated buffers),
    realising the paper's preallocation/reuse optimization.
    """
    p = _plain_params(params)
    batch = x.shape[0]
    c0, h0 = init_state(cfg, batch, x.dtype)

    def step(carry, x_t):
        c, h = carry
        inp = x_t
        cs, hs = [], []
        for i in range(cfg.n_layers):
            c_i, h_i = cell_fn(p["layers"][i], inp, c[i], h[i])
            cs.append(c_i)
            hs.append(h_i)
            inp = h_i
        return (jnp.stack(cs), jnp.stack(hs)), None

    (c, h), _ = jax.lax.scan(step, (c0, h0), jnp.swapaxes(x, 0, 1))
    last = h[-1]
    return last @ p["head"]["w"] + p["head"]["b"]


def forward_fused_kernel(params: dict, x: jax.Array, cfg: LSTMConfig,
                         interpret: bool = True) -> jax.Array:
    """Sequential plan with the Pallas fused-cell kernel as the cell body."""
    from repro.kernels import ops as kernel_ops

    def cell_fn(p, inp, c, h):
        return kernel_ops.lstm_cell(p["w"], p["b"], inp, c, h,
                                    interpret=interpret)

    return forward_sequential(params, x, cfg, cell_fn=cell_fn)


def forward_fused_seq(params: dict, x: jax.Array, cfg: LSTMConfig,
                      interpret: bool = True,
                      vmem_budget: int | None = None) -> jax.Array:
    """Sequence-resident plan: ONE Pallas dispatch for the whole (T x L)
    recurrence (kernels/lstm_seq.py) — dispatch count O(1) in T instead of
    the per-cell plan's O(T*L).  Under ``jax.grad`` the custom VJP runs the
    trajectory-emitting forward plus ONE reverse-sweep BPTT dispatch
    (kernels/lstm_seq_bwd.py); when the backward working set (~3x the
    forward one) does not fit VMEM, the backward alone falls back to the
    oracle VJP while the forward stays fused.

    The tiling comes from ``choose_batch_block`` as a ``(block_b,
    time_chunk)`` pair: whole-T VMEM residency when it fits, otherwise the
    kernels STREAM the time axis through double-buffered chunks — long T is
    no longer a reason to leave the fused plan.  Only when even a
    ``(bm=1, tc=1)`` tiling cannot fit (the weight stack itself blows the
    budget) does this route to ``forward_fused_kernel``, whose per-cell
    kernel tiles the hidden dimension through HBM instead.
    """
    return _forward_fused_seq_impl(params, x, cfg, interpret=interpret,
                                   vmem_budget=vmem_budget, quantized=False)


def _forward_fused_seq_impl(params: dict, x: jax.Array, cfg: LSTMConfig, *,
                            interpret: bool, vmem_budget: int | None,
                            quantized: bool) -> jax.Array:
    """Shared body of the f32 and int8 sequence-resident plans: stack the
    layer params, consult the (quantization-aware) ``(block_b, time_chunk)``
    table for the fwd and bwd dispatches, fall back to the per-cell kernel
    when even (bm=1, tc=1) cannot fit, run the fused kernel, apply the
    head.  ``quantized`` flips the budget surface, casts the stack to f32
    masters (so int8 rounding is the ONLY deviation and straight-through
    grads land in f32 before the astype VJP returns them to param dtype),
    and dispatches the q8 kernel."""
    from repro.kernels import lstm_seq as seq_lib
    from repro.kernels import ops as kernel_ops

    p = _plain_params(params)
    w_stack, b_stack, p_width = seq_lib.stack_params(p["layers"], cfg.hidden)
    if quantized:
        w_stack = w_stack.astype(jnp.float32)
        b_stack = b_stack.astype(jnp.float32)
        w_bytes = None                  # 1 byte/weight via quantized=True
    else:
        w_bytes = jnp.dtype(w_stack.dtype).itemsize
    B, T, _ = x.shape
    dtype_bytes = jnp.dtype(x.dtype).itemsize
    blocks = seq_lib.choose_batch_block(
        B, T, cfg.n_layers, p_width, cfg.hidden,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        w_dtype_bytes=w_bytes, quantized=quantized)
    from repro.obs import trace as trace_lib
    tracer = trace_lib.get_tracer()
    plan_name = "fused_seq_q8" if quantized else "fused_seq"
    if blocks is None:    # weight stack > VMEM even at (bm=1, tc=1)
        if tracer.enabled:   # the silent fallback, made visible
            tracer.event("plan/dispatch", family="lstm", plan=plan_name,
                         fallback="fused_cell", batch=B, seq_len=T)
        return forward_fused_kernel(params, x, cfg, interpret=interpret)
    bwd_blocks = seq_lib.choose_batch_block(
        B, T, cfg.n_layers, p_width, cfg.hidden,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        w_dtype_bytes=w_bytes, mode="bwd", quantized=quantized)
    xp = seq_lib.pad_input(x, p_width)
    if bwd_blocks is None:
        bwd_kw = dict(bwd_block_b=seq_lib.ORACLE_BWD)
    else:
        bwd_kw = dict(bwd_block_b=bwd_blocks.block_b,
                      bwd_time_chunk=bwd_blocks.time_chunk)
    if tracer.enabled:
        tracer.event("plan/dispatch", family="lstm", plan=plan_name,
                     block_b=blocks.block_b, time_chunk=blocks.time_chunk,
                     bwd_block_b=bwd_kw.get("bwd_block_b"),
                     bwd_time_chunk=bwd_kw.get("bwd_time_chunk"),
                     batch=B, seq_len=T)
    op = kernel_ops.lstm_seq_q8 if quantized else kernel_ops.lstm_seq
    _, h = op(w_stack, b_stack, xp, block_b=blocks.block_b,
              time_chunk=blocks.time_chunk, interpret=interpret, **bwd_kw)
    return h[-1] @ p["head"]["w"] + p["head"]["b"]


def forward_fused_seq_q8(params: dict, x: jax.Array, cfg: LSTMConfig,
                         interpret: bool = True,
                         vmem_budget: int | None = None) -> jax.Array:
    """Int8-weight sequence-resident plan: the ``fused_seq`` fast path with
    the stacked weights quantized to per-output-channel symmetric int8
    (kernels/ref.quantize_q8) and dequantized on the fly inside the fused
    kernels.  The dominant VMEM term — the (L, P+H, 4H) weight stack —
    shrinks 4x, so ``choose_batch_block(quantized=True)`` keeps whole-T
    residency deeper into T and coarser batch tiles at budgets where the
    f32 plan must stream or fall back, and the streamed-HBM roofline sees
    ~4x less weight traffic (analysis.lstm_seq_stream_costs).

    NOT numerically equivalent to the other plans: it matches the
    dequantize oracle within fp rounding, and the f32 plans within the
    documented int8 error band (tests/test_plan_equivalence.py).  Under
    ``jax.grad`` the straight-through q8 reverse sweep keeps
    ``value_and_grad`` at exactly 2 dispatches; masters stay f32, so the
    plan is a drop-in quantization-aware-training choice.
    """
    return _forward_fused_seq_impl(params, x, cfg, interpret=interpret,
                                   vmem_budget=vmem_budget, quantized=True)


def forward_wavefront(params: dict, x: jax.Array, cfg: LSTMConfig
                      ) -> jax.Array:
    """Paper Fig 1 diagonal plan — see core/wavefront.py."""
    from repro.core import wavefront
    return wavefront.forward_wavefront(params, x, cfg)


#: All five execution plans, keyed by scheduler Plan name — the registration
#: table used by benchmarks/run.py, examples/quickstart.py, and the
#: equivalence tests.  Every entry maps (params, x, cfg) -> logits.  The
#: first four are numerically equivalent; ``fused_seq_q8`` is the
#: int8-weight variant of ``fused_seq`` and matches the others only within
#: the documented int8 error band (see its docstring).
FORWARD_PLANS: dict[str, Callable] = {
    "sequential": forward_sequential,
    "wavefront": forward_wavefront,
    "fused_cell": forward_fused_kernel,
    "fused_seq": forward_fused_seq,
    "fused_seq_q8": forward_fused_seq_q8,
}


def plan_viability(cfg: LSTMConfig, batch: int, seq_len: int, *,
                   seq_plan_names: tuple[str, ...] = ("fused_seq",),
                   q8_plan_names: tuple[str, ...] = ("fused_seq_q8",),
                   dtype_bytes: int = 4, w_dtype_bytes: int | None = None,
                   vmem_budget: int | None = None,
                   train: bool = False) -> Callable[[str], bool]:
    """Viability predicate for ``Scheduler(viable=...)``.

    The sequence-resident plan is only a real plan while
    ``kernels/lstm_seq.choose_batch_block`` finds a ``(block_b,
    time_chunk)`` tiling whose working set fits VMEM — whole-T residency or
    double-buffered time streaming; past the budget ``forward_fused_seq``
    silently reroutes to the per-cell kernel, so calibrating or choosing it
    would just duplicate ``fused_cell`` under a misleading name.  With time
    streaming, long T alone never disqualifies the plan — only a weight
    stack (plus its gradient accumulators, under ``train=True``) that blows
    the budget at ``(bm=1, tc=1)`` does.  ``seq_plan_names`` lists the
    scheduler names registered for the sequence-resident plan (benchmarks
    register it as ``accel_seq``).  All other plan names are always viable.

    ``train=True`` sizes the BACKWARD working set instead
    (``choose_batch_block(mode="bwd")``: trajectory residuals + gradient
    accumulators, ~3x the forward) — the number that matters when the
    scheduled step runs under ``jax.grad``.  Without it the scheduler can
    pick ``fused_seq`` for a training step whose backward residuals blow
    the VMEM budget and silently drops to the oracle VJP, i.e. the slow
    path under the fast plan's name.

    ``q8_plan_names`` lists the scheduler names of the INT8-weight
    sequence-resident plan (``fused_seq_q8``); its viability surface is the
    quantization-aware table (``choose_batch_block(quantized=True)``: 1-byte
    weight stack + f32 scales; f32 dw/db outs under ``train=True``) — a
    strictly-no-smaller window than the f32 plan's, so there are budgets
    where the scheduler may only offer the quantized fast path.
    """
    from repro.kernels import lstm_seq as seq_lib

    p_width = max(cfg.input_dim, cfg.hidden)
    mode = "bwd" if train else "fwd"
    block = seq_lib.choose_batch_block(
        batch, seq_len, cfg.n_layers, p_width, cfg.hidden,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget,
        w_dtype_bytes=w_dtype_bytes, mode=mode)
    q8_block = seq_lib.choose_batch_block(
        batch, seq_len, cfg.n_layers, p_width, cfg.hidden,
        dtype_bytes=dtype_bytes, vmem_budget=vmem_budget, mode=mode,
        quantized=True)

    def viable(plan_name: str) -> bool:
        if plan_name in q8_plan_names:
            return q8_block is not None
        return block is not None or plan_name not in seq_plan_names

    return viable


def loss_fn(params: dict, x: jax.Array, labels: jax.Array, cfg: LSTMConfig,
            forward: Callable = forward_sequential) -> jax.Array:
    logits = forward(params, x, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(params: dict, x: jax.Array, labels: jax.Array, cfg: LSTMConfig,
             forward: Callable = forward_sequential) -> jax.Array:
    logits = forward(params, x, cfg)
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
