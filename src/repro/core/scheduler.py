"""Load-aware execution-plan dispatch (paper §4.5, Fig 7).

MobiRNN's finding: the accelerator is shared (UI rendering on the mobile
GPU); under low/medium load offloading wins, under high load the CPU path is
faster — so the runtime must *sense load and choose*.  Here the same engine
drives serving-plan selection: each registered ``Plan`` carries a calibrated
base latency and a contention model; a pluggable ``LoadSensor`` supplies the
current load; ``Scheduler.choose`` picks the predicted-fastest plan and
``Scheduler.record`` folds observed latencies back into the calibration
(exponential moving average), so the crossover point is learned, not assumed.

The five LSTM execution plans it schedules (core/lstm.FORWARD_PLANS; see
that module's docstring for the full decision table):

* ``sequential`` / ``wavefront`` — XLA plans; the CPU-ish and
  diagonal-parallel baselines.
* ``fused_cell`` — per-cell Pallas kernel, T x L dispatches.  Wins in
  compute-bound regimes (H too large for VMEM-resident weights).
* ``fused_seq`` — sequence-resident Pallas kernel, ONE dispatch.  Wins in
  dispatch-bound regimes (the MobiRNN case: small models, long sequences).
  Its viability surface is the joint ``(block_b, time_chunk)`` table of
  kernels/lstm_seq.choose_batch_block: whole-T VMEM residency when it
  fits, double-buffered time streaming past that — so long T alone never
  disqualifies it; only a weight stack that blows the budget at
  ``(bm=1, tc=1)`` routes to ``fused_cell`` (wire the table in via
  ``Scheduler(viable=core/lstm.plan_viability(...))``, with
  ``train=True`` for training-step schedulers).
* ``fused_seq_q8`` — the sequence-resident plan over int8-quantized
  weights.  Same dispatch profile as ``fused_seq`` but its viability
  surface is the QUANTIZATION-AWARE budget table
  (``choose_batch_block(quantized=True)``: 1-byte weight stack + f32
  scales), so under tight VMEM it stays schedulable — whole-T resident,
  coarse-tiled — where the f32 plan must stream or drops out entirely;
  ``plan_viability`` sizes both surfaces so the per-tick Fig 7 choice sees
  the 4x smaller weight term.  Accuracy contract: int8 error band, not
  bit-equality — register it only where that band is acceptable.

The scheduler itself is FAMILY-GENERIC: ``viable=`` is just a predicate
over registered plan names, and core/plans.py is where families (lstm,
rwkv6) publish their plans, equivalence policies, and the VMEM working-set
models that build those predicates.  A multi-family scheduler combines
them with ``plans.scheduler_viability({scheduler_name: (family_plan,
family_predicate)})`` — e.g. ``accel_seq`` bound to the lstm family's
``fused_seq`` via ``lstm.plan_viability(...)`` and ``accel_wkv`` bound to
rwkv6's ``chunked_scan`` via ``plans.rwkv_viability(...)``; unbound names
(CPU fallbacks) stay always-viable.  Non-viable plans are never calibrated
and never chosen, exactly as for the single-family case.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Protocol

from repro.obs import trace as trace_lib


class LoadSensor(Protocol):
    def load(self) -> float: ...          # in [0, 1]


@dataclasses.dataclass
class SyntheticLoadSensor:
    """Injected load — used by tests and the Fig 7 reproduction."""
    value: float = 0.0

    def load(self) -> float:
        return min(max(self.value, 0.0), 1.0)


class ProcLoadSensor:
    """Real sensor: normalised 1-minute loadavg (the /proc analogue of the
    paper's ADB / Adreno utilisation scripts)."""

    def __init__(self, n_cpus: int | None = None):
        import os
        self.n_cpus = n_cpus or os.cpu_count() or 1

    def load(self) -> float:
        import os
        try:
            return min(os.getloadavg()[0] / self.n_cpus, 1.0)
        except OSError:  # pragma: no cover
            return 0.0


@dataclasses.dataclass
class Plan:
    """An executable plan with a latency-vs-load contention model.

    ``shared``: whether the plan contends with the sensed load (the paper's
    GPU is shared with rendering; a dedicated CPU reservation is not).
    predicted(load) = base / max(eps, 1 - sensitivity * load)  when shared.
    """
    name: str
    fn: Callable
    base_latency_s: float = float("inf")
    shared: bool = True
    sensitivity: float = 1.0
    ema: float = 0.3

    def predicted(self, load: float) -> float:
        if not self.shared:
            return self.base_latency_s
        denom = max(1e-3, 1.0 - self.sensitivity * load)
        return self.base_latency_s / denom

    def observe(self, latency_s: float, load: float) -> None:
        # invert the contention model to update the base estimate
        if self.shared:
            latency_s = latency_s * max(1e-3, 1.0 - self.sensitivity * load)
        if self.base_latency_s == float("inf"):
            self.base_latency_s = latency_s
        else:
            self.base_latency_s = ((1 - self.ema) * self.base_latency_s
                                   + self.ema * latency_s)


@dataclasses.dataclass
class Decision:
    plan: str
    load: float
    predicted_s: dict[str, float]


class Scheduler:
    """``viable`` is an optional predicate ``plan_name -> bool`` filtering
    plans that cannot run at all on the current shapes (e.g. the
    sequence-resident kernel past its VMEM budget,
    kernels/lstm_seq.choose_batch_block -> None; see core/lstm.plan_viability
    for the wiring).  Non-viable plans are never calibrated and never chosen
    — calibrating one would waste a warm-up dispatch on a plan that only
    ever runs its fallback path, and choosing one would silently benchmark
    the fallback under the wrong name."""

    #: decision-history bound: the slot engine calls choose() once per
    #: decode tick for the engine's whole life, so an unbounded list would
    #: be a slow host-memory leak on the serving hot loop
    MAX_DECISIONS = 4096

    def __init__(self, sensor: LoadSensor,
                 viable: Callable[[str], bool] | None = None,
                 ladder: list[str] | None = None):
        import collections
        self.sensor = sensor
        self.viable = viable
        self.plans: dict[str, Plan] = {}
        self.decisions: collections.deque[Decision] = collections.deque(
            maxlen=self.MAX_DECISIONS)
        #: graceful-degradation ladder: plan names ordered most-expensive
        #: first.  ``level`` rungs are currently excluded from choose() —
        #: the serving watchdog steps it via degrade()/recover()
        self.ladder: list[str] = list(ladder or [])
        self.level: int = 0

    def register(self, plan: Plan) -> None:
        self.plans[plan.name] = plan

    def _demoted(self) -> set[str]:
        return set(self.ladder[:self.level])

    def _viable_plans(self, viable: Callable[[str], bool] | None
                      ) -> dict[str, Plan]:
        pred = self.viable if viable is None else viable
        demoted = self._demoted()
        out = {n: p for n, p in self.plans.items()
               if (pred is None or pred(n)) and n not in demoted}
        if not out:
            raise ValueError(
                f"no viable plan among {sorted(self.plans)} — the viability "
                "predicate (or degradation level "
                f"{self.level}/{self.ladder}) rejected every registered plan")
        return out

    def degrade(self, reason: str = "slo") -> bool:
        """Step one rung down the ladder: exclude the next (most expensive
        still-included) ladder plan from choose()/calibrate().  Refuses —
        returns False, state unchanged — when the ladder is spent or when
        stepping down would leave no viable plan; the engine must always
        have SOMETHING to run, degraded or not."""
        if self.level >= len(self.ladder):
            return False
        self.level += 1
        try:
            self._viable_plans(None)
        except ValueError:
            self.level -= 1
            return False
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("sched/degrade", level=self.level,
                         excluded=sorted(self._demoted()), reason=reason)
        return True

    def recover(self) -> bool:
        """Step one rung back up (re-admit the most recently demoted plan).
        Returns False at level 0."""
        if self.level == 0:
            return False
        self.level -= 1
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("sched/recover", level=self.level,
                         excluded=sorted(self._demoted()))
        return True

    @staticmethod
    def _blocked_call(plan: Plan, args, kwargs):
        out = plan.fn(*args, **kwargs)
        try:  # block on async results
            import jax
            out = jax.block_until_ready(out)
        except Exception:
            pass
        return out

    def calibrate(self, *args, repeats: int = 3,
                  viable: Callable[[str], bool] | None = None,
                  profile: Mapping[str, float] | None = None,
                  **kwargs) -> None:
        """Seed base latencies for each viable plan; non-viable plans keep
        base_latency_s = inf.

        A plan named in ``profile`` (plan name -> measured seconds, e.g.
        ``obs.profile.DeviceProfile.best_latencies(...)``) is seeded from
        the persisted measurement WITHOUT running — the measured-profiler
        path that replaces cold analytic estimates.  Every other viable
        plan is timed here: ONE untimed warmup call first (absorbing JIT
        compile — without it ``repeats=1`` records compile time as the
        base latency), then best-of-``repeats`` timed calls.
        """
        tracer = trace_lib.get_tracer()
        for plan in self._viable_plans(viable).values():
            if profile is not None and plan.name in profile:
                plan.base_latency_s = float(profile[plan.name])
                if tracer.enabled:
                    tracer.event("sched/calibrate", plan=plan.name,
                                 latency_s=plan.base_latency_s,
                                 source="profile")
                continue
            self._blocked_call(plan, args, kwargs)          # untimed warmup
            best = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                self._blocked_call(plan, args, kwargs)
                best = min(best, time.perf_counter() - t0)
            plan.base_latency_s = best
            if tracer.enabled:
                tracer.event("sched/calibrate", plan=plan.name,
                             latency_s=best, source="measured")

    def choose(self, load: float | None = None,
               viable: Callable[[str], bool] | None = None) -> Decision:
        load = self.sensor.load() if load is None else load
        preds = {n: p.predicted(load)
                 for n, p in self._viable_plans(viable).items()}
        best = min(preds, key=preds.get)
        d = Decision(plan=best, load=load, predicted_s=preds)
        self.decisions.append(d)
        tracer = trace_lib.get_tracer()
        if tracer.enabled:
            tracer.event("sched/choose", plan=best, load=load,
                         predicted_s=preds[best], n_viable=len(preds))
        return d

    def run(self, *args, **kwargs):
        d = self.choose()
        plan = self.plans[d.plan]
        tracer = trace_lib.get_tracer()
        span = (tracer.span("sched/run", plan=d.plan, load=d.load)
                if tracer.enabled else trace_lib.NULL_SPAN)
        with span:
            t0 = time.perf_counter()
            out = self._blocked_call(plan, args, kwargs)
            latency = time.perf_counter() - t0
            span.set(latency_s=latency)
        plan.observe(latency, d.load)
        return out, d
