"""Wavefront (anti-diagonal) execution of stacked recurrent layers.

Paper Fig 1: in a stacked RNN, cell (layer i, time t) depends only on
(i-1, t) and (i, t-1); all cells with equal i+t are independent and can run
concurrently.  MobiRNN exploits this on the mobile GPU and bounds the live
state to 2 x wavefront-width buffers (6 instead of 24 in the paper's figure).

TPU realisation: each diagonal executes as ONE vmapped fused-cell call over
the layer dimension — a single (L, B, 2H) x (L, 2H, 4H) batched matmul, i.e.
a coarse work unit in MobiRNN's sense, instead of L small sequential ones.
The carry is exactly 2 state buffers of wavefront width plus a 1-deep "belt"
of inter-layer activations, matching the paper's preallocation bound.

Numerical equivalence with the sequential plan is asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.partitioning import split


def wavefront_width(n_layers: int, seq_len: int) -> int:
    """Maximum number of concurrently-executable cells (paper: 3 for 3x4)."""
    return min(n_layers, seq_len)


def live_buffers(n_layers: int, seq_len: int) -> int:
    """State buffers MobiRNN preallocates: (c,h) per wavefront slot."""
    return 2 * wavefront_width(n_layers, seq_len)


def stack_homogeneous(params: dict, cfg: LSTMConfig
                      ) -> tuple[jax.Array, jax.Array, int]:
    """Stack per-layer cell params to (L, P+H, 4H) / (L, 4H).

    To vmap one cell over all layers, every layer's input rows are
    zero-padded to the common width P = max(input_dim, H) and inputs are
    zero-padded to P at call time (padded rows multiply padded zeros —
    exactly equivalent math).  For the paper's models input_dim <= H, so
    P = H and the stack is the (L, 2H, 4H) of Fig 1.  Shared with the
    sequence-resident kernel: kernels/lstm_seq.stack_params is the
    un-annotated twin of this function.

    Returns (w_stack, b_stack, P).
    """
    from repro.kernels.lstm_seq import stack_params
    p, _ = split(params)
    return stack_params(p["layers"], cfg.hidden)


def forward_wavefront(params: dict, x: jax.Array, cfg: LSTMConfig) -> jax.Array:
    """x: (batch, seq, input_dim) -> logits (batch, n_classes)."""
    p, _ = split(params)
    L, H = cfg.n_layers, cfg.hidden
    B, T, D = x.shape
    w_stack, b_stack, P = stack_homogeneous(params, cfg)  # (L,P+H,4H), ..

    # time-padded, P-padded input belt source: x_pad[t] valid for t < T
    x_pad = jnp.zeros((T + L, B, P), x.dtype)
    x_pad = x_pad.at[:T, :, :D].set(jnp.swapaxes(x, 0, 1))

    def diag_cell(w, b, inp, c, h):
        xh = jnp.concatenate([inp, h], axis=-1)
        gates = xh @ w + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        return c_new, h_new

    vcell = jax.vmap(diag_cell)  # over the layer (wavefront) dimension

    c0 = jnp.zeros((L, B, H), x.dtype)
    h0 = jnp.zeros((L, B, H), x.dtype)
    belt0 = jnp.zeros((L, B, P), x.dtype)   # belt[i] = input for layer i
    layer_ids = jnp.arange(L)

    def diagonal(carry, d):
        c, h, belt = carry
        # layer i processes time t = d - i; active iff 0 <= t < T
        t = d - layer_ids
        active = (t >= 0) & (t < T)
        # layer 0's input comes from x at time d (zeros when d >= T)
        inp = belt.at[0].set(x_pad[jnp.minimum(d, T + L - 1)])
        c_new, h_new = vcell(w_stack, b_stack, inp, c, h)
        mask = active[:, None, None]
        c = jnp.where(mask, c_new, c)
        h = jnp.where(mask, h_new, h)
        # belt shifts down one layer: layer i+1's next input is layer i's h
        h_belt = h if P == H else \
            jnp.pad(h, ((0, 0), (0, 0), (0, P - H)))
        belt = jnp.concatenate([jnp.zeros_like(h_belt[:1]), h_belt[:-1]],
                               axis=0)
        return (c, h, belt), None

    (c, h, _), _ = jax.lax.scan(
        diagonal, (c0, h0, belt0), jnp.arange(L + T - 1))
    last = h[-1]
    return last @ p["head"]["w"] + p["head"]["b"]
