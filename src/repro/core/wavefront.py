"""Wavefront (anti-diagonal) execution of stacked recurrent layers.

Paper Fig 1: in a stacked RNN, cell (layer i, time t) depends only on
(i-1, t) and (i, t-1); all cells with equal i+t are independent and can run
concurrently.  MobiRNN exploits this on the mobile GPU and bounds the live
state to 2 x wavefront-width buffers (6 instead of 24 in the paper's figure).

TPU realisation: each diagonal executes as ONE vmapped fused-cell call over
the layer dimension — a single (L, B, 2H) x (L, 2H, 4H) batched matmul, i.e.
a coarse work unit in MobiRNN's sense, instead of L small sequential ones.
The carry is exactly 2 state buffers of wavefront width plus a 1-deep "belt"
of inter-layer activations, matching the paper's preallocation bound.

Numerical equivalence with the sequential plan is asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.partitioning import split


def wavefront_width(n_layers: int, seq_len: int) -> int:
    """Maximum number of concurrently-executable cells (paper: 3 for 3x4)."""
    return min(n_layers, seq_len)


def live_buffers(n_layers: int, seq_len: int) -> int:
    """State buffers MobiRNN preallocates: (c,h) per wavefront slot."""
    return 2 * wavefront_width(n_layers, seq_len)


def stack_homogeneous(params: dict, cfg: LSTMConfig) -> tuple[jax.Array, jax.Array]:
    """Stack per-layer cell params to (L, 2H, 4H) / (L, 4H).

    Layer 0 consumes ``input_dim``-dim inputs; to vmap one cell over all
    layers, its weight rows are zero-padded from (input_dim + H) to 2H and
    the raw input is zero-padded to H at call time.  Exactly equivalent math.
    """
    p, _ = split(params)
    ws, bs = [], []
    h = cfg.hidden
    for i, layer in enumerate(p["layers"]):
        w = layer["w"]
        in_dim = w.shape[0] - h
        if in_dim < h:
            pad = jnp.zeros((h - in_dim, 4 * h), w.dtype)
            w = jnp.concatenate([w[:in_dim], pad, w[in_dim:]], axis=0)
        ws.append(w)
        bs.append(layer["b"])
    return jnp.stack(ws), jnp.stack(bs)


def forward_wavefront(params: dict, x: jax.Array, cfg: LSTMConfig) -> jax.Array:
    """x: (batch, seq, input_dim) -> logits (batch, n_classes)."""
    p, _ = split(params)
    L, H = cfg.n_layers, cfg.hidden
    B, T, D = x.shape
    w_stack, b_stack = stack_homogeneous(params, cfg)  # (L,2H,4H), (L,4H)

    # time-padded, H-padded input belt source: x_pad[t] valid for t < T
    x_pad = jnp.zeros((T + L, B, H), x.dtype)
    x_pad = x_pad.at[:T, :, :D].set(jnp.swapaxes(x, 0, 1))

    def diag_cell(w, b, inp, c, h):
        xh = jnp.concatenate([inp, h], axis=-1)
        gates = xh @ w + b
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        return c_new, h_new

    vcell = jax.vmap(diag_cell)  # over the layer (wavefront) dimension

    c0 = jnp.zeros((L, B, H), x.dtype)
    h0 = jnp.zeros((L, B, H), x.dtype)
    belt0 = jnp.zeros((L, B, H), x.dtype)   # belt[i] = input for layer i
    layer_ids = jnp.arange(L)

    def diagonal(carry, d):
        c, h, belt = carry
        # layer i processes time t = d - i; active iff 0 <= t < T
        t = d - layer_ids
        active = (t >= 0) & (t < T)
        # layer 0's input comes from x at time d (zeros when d >= T)
        inp = belt.at[0].set(x_pad[jnp.minimum(d, T + L - 1)])
        c_new, h_new = vcell(w_stack, b_stack, inp, c, h)
        mask = active[:, None, None]
        c = jnp.where(mask, c_new, c)
        h = jnp.where(mask, h_new, h)
        # belt shifts down one layer: layer i+1's next input is layer i's h
        belt = jnp.concatenate([jnp.zeros_like(h[:1]), h[:-1]], axis=0)
        return (c, h, belt), None

    (c, h, _), _ = jax.lax.scan(
        diagonal, (c0, h0, belt0), jnp.arange(L + T - 1))
    last = h[-1]
    return last @ p["head"]["w"] + p["head"]["b"]
