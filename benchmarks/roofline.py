"""Roofline table generator: reads results/dryrun/*.json and emits the
per-(arch x shape x mesh) three-term table for EXPERIMENTS.md §Roofline,
plus the streamed fused-LSTM roofline (no dryrun records needed): per-chunk
HBM traffic vs compute for the time-chunked, double-buffered kernels."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_seconds(x: float) -> str:
    if x == 0:
        return "0"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    if x >= 1e-6:
        return f"{x * 1e6:.0f}us"
    return f"{x * 1e9:.0f}ns"


def one_liner(r: dict) -> str:
    """What would move the dominant term down (per-record suggestion)."""
    roof = r["roofline"]
    dom = roof["dominant"]
    kind = r["kind"]
    if dom == "collective":
        if kind == "train":
            return ("overlap gradient reduce-scatter with backprop; widen "
                    "per-layer all-reduces into the layer scan")
        return ("shard decode cache by heads where divisible instead of "
                "seq; batch collective-permute steps")
    if dom == "memory":
        if kind == "decode":
            return ("quantise/shrink the KV cache (window, GQA-packing); "
                    "decode is cache-bandwidth-bound")
        return "recompute less (remat policy), fuse norms into matmuls"
    if roof["useful_flops_frac"] < 0.5:
        return ("cut non-useful compute: causal-skip attention blocks, "
                "lower capacity factor, cheaper remat policy")
    return "compute-bound near peak: increase per-chip batch or chips"


def table(recs: list[dict], mesh: str = "pod1") -> str:
    rows = [("arch", "shape", "t_comp", "t_mem", "t_coll", "dominant",
             "useful", "mfu_bound")]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        t = max(roof["t_compute_s"], roof["t_memory_s"],
                roof["t_collective_s"])
        mfu = (roof["model_flops"]
               / (roof["n_chips"] * 197e12) / t if t else 0.0)
        rows.append((
            r["arch"], r["shape"],
            fmt_seconds(roof["t_compute_s"]),
            fmt_seconds(roof["t_memory_s"]),
            fmt_seconds(roof["t_collective_s"]),
            roof["dominant"],
            f"{roof['useful_flops_frac']:.2f}",
            f"{mfu:.2%}",
        ))
    widths = [max(len(str(row[i])) for row in rows)
              for i in range(len(rows[0]))]
    lines = []
    for i, row in enumerate(rows):
        lines.append(" | ".join(str(c).ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("-|-".join("-" * w for w in widths))
    return "\n".join(lines)


def fused_lstm_stream_table(batch: int = 8, hidden: int = 128,
                            n_layers: int = 2, input_dim: int = 9) -> str:
    """Roofline of the time-chunked fused-LSTM kernels across T.

    For each sequence length: the whole-T-resident VMEM footprint, the
    chosen ``(block_b, time_chunk)`` under the default budget, the streamed
    HBM bytes per dispatch (input + trajectory + dx traffic — what the
    double buffer must hide behind compute) and the two roofline terms.
    The ``bound`` column says which side the pipeline saturates: when
    ``t_mem`` dominates, a deeper chunk cannot help — the kernel is
    genuinely bandwidth-bound; when ``t_comp`` dominates, the streaming is
    free (fully hidden behind the MXU work).

    The ``fwd_q8``/``bwd_q8`` rows repeat the table for the int8-weight
    plan (``fused_seq_q8``): the streamed weight term is ~4x smaller and
    the chosen tiling no finer, so the rows show how much of the bandwidth
    bound quantization buys back at each T.
    """
    from repro import analysis
    from repro.kernels import lstm_seq as seq_lib

    p_width = max(input_dim, hidden)
    rows = [("mode", "T", "blocks(bm,tc)", "resident", "streamed",
             "t_comp", "t_mem", "bound")]
    for mode in ("fwd", "bwd"):
        for quantized in (False, True):
            label = mode + ("_q8" if quantized else "")
            for T in (128, 512, 2048, 8192):
                blocks = seq_lib.choose_batch_block(
                    batch, T, n_layers, p_width, hidden, mode=mode,
                    quantized=quantized)
                if blocks is None:
                    rows.append((label, T, "none (per-cell/oracle)", "-",
                                 "-", "-", "-", "-"))
                    continue
                costs = analysis.lstm_seq_stream_costs(
                    T, n_layers, p_width, hidden, batch, blocks.block_b,
                    blocks.time_chunk, mode=mode, quantized=quantized)
                bound = ("memory" if costs["t_memory"] > costs["t_compute"]
                         else "compute")
                rows.append((
                    label, T, f"({blocks.block_b},{blocks.time_chunk})",
                    f"{costs['vmem_resident_bytes'] / 2**20:.2f}MB",
                    f"{costs['hbm_bytes'] / 2**20:.2f}MB",
                    fmt_seconds(costs["t_compute"]),
                    fmt_seconds(costs["t_memory"]), bound))
    widths = [max(len(str(row[i])) for row in rows)
              for i in range(len(rows[0]))]
    lines = []
    for i, row in enumerate(rows):
        lines.append(" | ".join(str(c).ljust(w) for c, w in zip(row, widths)))
        if i == 0:
            lines.append("-|-".join("-" * w for w in widths))
    return "\n".join(lines)


def main() -> None:
    try:
        stream_table = fused_lstm_stream_table()
    except ImportError:
        stream_table = ("repro not importable — run with PYTHONPATH=src "
                        "for the streamed fused-LSTM roofline")
    print("=== streamed fused-LSTM roofline (time-chunked kernels) ===")
    print(stream_table)
    recs = load()
    if not recs:
        print("\nno dry-run records; run python -m repro.launch.dryrun first")
        return
    for mesh in ("pod1", "pod2"):
        n = sum(r["mesh"] == mesh for r in recs)
        print(f"\n=== mesh {mesh} ({n} records) ===")
        print(table(recs, mesh))
    # hillclimb candidates
    recs1 = [r for r in recs if r["mesh"] == "pod1"]
    by_frac = sorted(recs1, key=lambda r: r["roofline"]["useful_flops_frac"])
    by_coll = sorted(recs1, key=lambda r: -r["roofline"]["t_collective_s"])
    print("\nworst useful-flops fraction:",
          [(r["arch"], r["shape"],
            round(r["roofline"]["useful_flops_frac"], 3))
           for r in by_frac[:3]])
    print("most collective-bound:",
          [(r["arch"], r["shape"],
            fmt_seconds(r["roofline"]["t_collective_s"]))
           for r in by_coll[:3]])


if __name__ == "__main__":
    main()
