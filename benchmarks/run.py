"""Benchmark harness — one benchmark per paper figure, plus framework-level
kernel/scan benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

Paper figures (MobiRNN, EMDL'17) and their analogues here:
  Fig 2/3  work-unit factorization: fine (per-column) vs packed vs fused —
           empirical wall time on this host + the calibrated device model
           (core/factorization) that reproduces the paper's mobile-GPU
           numbers.
  Fig 4    GPU-vs-CPU speedup for the default 2x32 model (device model) +
           empirical fused-vs-fine speedup.
  Fig 5    speedup vs model complexity (hidden units / layers sweep).
  Fig 6    multi-threaded CPU vs GPU (device model: >= 70% claim).
  Fig 7    latency vs load + dispatch crossover (scheduler, synthetic load).

Framework benches: Pallas kernels (interpret), rwkv chunk-size sweep (the
work-unit-coarseness knob measured empirically), MoE capacity-factor sweep.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MOBIRNN_LSTM
from repro.core import cell as cell_lib
from repro.core import factorization as fz
from repro.core import lstm
from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def timeit(fn, *args, repeats: int = 5, **kw) -> float:
    fn(*args, **kw)  # compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
def bench_fig3_factorization() -> None:
    cfg = MOBIRNN_LSTM
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.seq_len,
                                                  cfg.input_dim))

    def make(cell_fn):
        return jax.jit(lambda p, x: lstm.forward_sequential(p, x, cfg,
                                                            cell_fn=cell_fn))

    t_fine1 = timeit(make(lambda p, i, c, h: cell_lib.lstm_cell_fine(
        p, i, c, h, unit_cols=1)), params, x)
    t_fine10 = timeit(make(lambda p, i, c, h: cell_lib.lstm_cell_fine(
        p, i, c, h, unit_cols=10)), params, x)
    t_fused = timeit(make(cell_lib.lstm_cell_fused), params, x)
    row("fig3/fine_per_column", t_fine1, f"slowdown_vs_fused="
        f"{t_fine1 / t_fused:.2f}x")
    row("fig3/packed_10col", t_fine10,
        f"slowdown_vs_fused={t_fine10 / t_fused:.2f}x")
    row("fig3/fused", t_fused, "MobiRNN plan")
    # device-model reproduction of the paper's Fig 3 (4x slower on GPU)
    in_dim = cfg.input_dim + cfg.hidden
    t_gpu_fine = fz.factorize_gate(fz.MOBILE_GPU, in_dim, 4 * cfg.hidden, 1)
    t_cpu = fz.factorize_gate(fz.MOBILE_CPU1, in_dim, 4 * cfg.hidden,
                              4 * cfg.hidden)
    row("fig3/model_mobile_gpu_fine_vs_cpu", t_gpu_fine * 1e6,
        f"gpu_fine/cpu={t_gpu_fine / t_cpu:.2f}x (paper: ~4x slower)")


#: Mobile-class VMEM budget for the streamed fig2 family: whole-T residency
#: falls off it by T=512 (bwd) / T=2048 (fwd) at the seed config, so the
#: rows demonstrate the time-chunked pipeline keeping the plan fused where
#: it previously fell back.  Shared with the acceptance tests via
#: core/factorization so everything asserts one viability surface.
STREAM_BUDGET = fz.MOBILE_VMEM_BUDGET


def bench_fig2_dispatch_counts() -> None:
    """Fig 2/3's real lever, measured at the jaxpr level: kernel dispatches
    per forward AND per training step.  The per-cell fused plan launches one
    pallas_call per cell per step (O(T*L), and its VJP unrolls to O(T*L)
    again); the sequence-resident plan (kernels/lstm_seq.py +
    lstm_seq_bwd.py) launches exactly ONE forward and, under
    ``value_and_grad``, one forward + one reverse-sweep — O(1) in T both
    ways.  The ``stream_*`` rows repeat the count under the mobile-class
    STREAM_BUDGET: whole-T residency no longer fits there at long T, but
    the time-chunked double-buffered kernels keep the counts flat out to
    T=2048 — the ``nochunk`` note shows where the pre-streaming decision
    table (allow_chunk=False) would have fallen off the cliff."""
    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.kernels import lstm_seq as seq_lib

    cfg = MOBIRNN_LSTM
    p_width = max(cfg.input_dim, cfg.hidden)
    for T in (32, 128, 512, 2048):
        params = lstm.init_params(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, T, cfg.input_dim))
        labels = jnp.zeros((2,), jnp.int32)
        n_cell = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_kernel(p, x, cfg))(params, x))
        n_seq = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x))
        row(f"fig2/dispatch_fused_cell_T{T}", float(n_cell),
            f"pallas_calls={n_cell} (O(T*L))")
        row(f"fig2/dispatch_fused_seq_T{T}", float(n_seq),
            f"pallas_calls={n_seq} (O(1) in T)")
        t_cell = count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.forward_fused_kernel),
            params)
        t_seq = count_train_dispatches(
            lambda p: lstm.loss_fn(p, x, labels, cfg,
                                   forward=lstm.forward_fused_seq),
            params)
        row(f"fig2/train_dispatch_fused_cell_T{T}", float(t_cell),
            f"pallas_calls={t_cell} (fwd+bwd, O(T*L))")
        row(f"fig2/train_dispatch_fused_seq_T{T}", float(t_seq),
            f"pallas_calls={t_seq} (1 fwd + 1 bwd, O(1) in T)")

        # the same counts under the mobile-class budget: streamed kernels
        n_stream = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq(
                p, x, cfg, vmem_budget=STREAM_BUDGET))(params, x))
        t_stream = count_train_dispatches(
            lambda p: lstm.loss_fn(
                p, x, labels, cfg,
                forward=lambda p, x, cfg: lstm.forward_fused_seq(
                    p, x, cfg, vmem_budget=STREAM_BUDGET)),
            params)
        blocks = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=STREAM_BUDGET)
        nochunk = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=STREAM_BUDGET, allow_chunk=False)
        row(f"fig2/stream_dispatch_fused_seq_T{T}", float(n_stream),
            f"pallas_calls={n_stream},blocks={tuple(blocks) if blocks else None},"
            f"nochunk={'fused_seq' if nochunk else 'fused_cell-fallback'}")
        bwd_blocks = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=STREAM_BUDGET, mode="bwd")
        bwd_nochunk = seq_lib.choose_batch_block(
            2, T, cfg.n_layers, p_width, cfg.hidden,
            vmem_budget=STREAM_BUDGET, mode="bwd", allow_chunk=False)
        row(f"fig2/stream_train_dispatch_fused_seq_T{T}", float(t_stream),
            f"pallas_calls={t_stream},"
            f"bwd_blocks={tuple(bwd_blocks) if bwd_blocks else None},"
            f"nochunk={'fused-bwd' if bwd_nochunk else 'oracle-fallback'}")

    # wall time of the two kernel plans at the paper's default shape
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.input_dim))
    t_cell = timeit(jax.jit(lambda p, x: lstm.forward_fused_kernel(
        p, x, cfg)), params, x, repeats=2)
    t_seq = timeit(jax.jit(lambda p, x: lstm.forward_fused_seq(
        p, x, cfg)), params, x, repeats=2)
    row("fig2/time_fused_cell_T32", t_cell, "interpret-mode wall time")
    row("fig2/time_fused_seq_T32", t_seq,
        f"speedup_vs_percell={t_cell / t_seq:.2f}x")


def bench_chunk_sweep() -> None:
    """fig2/chunk_sweep: latency + dispatch count vs ``time_chunk`` at fixed
    T.  Dispatch count is flat at 1 by construction (the chunk loop lives
    INSIDE the kernel); wall time shows the streaming overhead curve — on
    real TPU the double buffer hides the DMA behind compute, in interpret
    mode the rows still pin down the shape of the overhead and that
    chunking never changes results (the kernels are bit-identical, asserted
    in tests)."""
    from repro.analysis import count_kernel_dispatches
    from repro.kernels import lstm_seq as seq_lib
    from repro.partitioning import split

    cfg = MOBIRNN_LSTM
    B, T = 4, 256
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.input_dim))
    values, _ = split(params)
    w_stack, b_stack, p_width = seq_lib.stack_params(values["layers"],
                                                     cfg.hidden)
    xp = seq_lib.pad_input(x, p_width)
    base = None
    for tc in (None, 128, 32, 8):
        fn = jax.jit(lambda w, b, xp, tc=tc: seq_lib.lstm_seq(
            w, b, xp, block_b=B, time_chunk=tc))
        t = timeit(fn, w_stack, b_stack, xp, repeats=2)
        n = count_kernel_dispatches(jax.make_jaxpr(
            lambda w, b, xp, tc=tc: seq_lib.lstm_seq(
                w, b, xp, block_b=B, time_chunk=tc))(w_stack, b_stack, xp))
        base = base or t
        label = "resident" if tc is None else f"tc{tc}"
        row(f"fig2/chunk_sweep_{label}", t,
            f"pallas_calls={n},vs_resident={base / t:.2f}x,T={T}")


def bench_stream_smoke() -> None:
    """CI smoke (fast job): at a T whose whole-T-resident working set
    exceeds the (constrained) budget, the fused plan must NOT fall back —
    forward stays 1 dispatch, value_and_grad stays 2, and the executed
    streamed kernels agree with the sequential oracle."""
    import numpy as np

    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.kernels import lstm_seq as seq_lib

    cfg = MOBIRNN_LSTM
    B, T = 2, 512
    p_width = max(cfg.input_dim, cfg.hidden)
    # the pre-streaming table would fall back at this (T, budget)...
    assert seq_lib.choose_batch_block(
        B, T, cfg.n_layers, p_width, cfg.hidden,
        vmem_budget=STREAM_BUDGET, mode="bwd", allow_chunk=False) is None
    # ...the chunked table must not
    bwd_blocks = seq_lib.choose_batch_block(
        B, T, cfg.n_layers, p_width, cfg.hidden,
        vmem_budget=STREAM_BUDGET, mode="bwd")
    assert bwd_blocks is not None and bwd_blocks.time_chunk is not None, \
        bwd_blocks

    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.input_dim))
    labels = jnp.zeros((B,), jnp.int32)

    def fwd(p, x, cfg):
        return lstm.forward_fused_seq(p, x, cfg, vmem_budget=STREAM_BUDGET)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: fwd(p, x, cfg))(params, x))
    n_train = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd), params)
    assert n_fwd == 1, f"streamed forward fell back: {n_fwd} dispatches"
    assert n_train == 2, f"streamed backward fell back: {n_train} dispatches"

    want = lstm.forward_sequential(params, x, cfg)
    got = fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    _, grads = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    assert all(bool(jnp.all(jnp.isfinite(g)))
               for g in jax.tree.leaves(grads))
    row("stream_smoke/long_T_fused", float(T),
        f"fwd_dispatches={n_fwd},train_dispatches={n_train},"
        f"bwd_blocks={tuple(bwd_blocks)},budget={STREAM_BUDGET}")


def bench_quant_rows() -> None:
    """quant/* rows: what int8 weights buy on the (T, 320K-budget) surface.

    For each T, fwd and bwd: the f32 vs q8 ``(block_b, time_chunk)`` choice
    under STREAM_BUDGET (the widened whole-T-resident window shows as
    ``tc=None`` where f32 already streams, and as coarser chunks past
    that), the streamed HBM bytes of the chosen tiling (the quartered
    weight term), and the q8 plan's dispatch counts — still 1 fwd / 2 train
    at every T (quantization happens in jnp outside the kernels).
    """
    from repro.analysis import (count_kernel_dispatches,
                                count_train_dispatches,
                                lstm_seq_stream_costs)
    from repro.kernels import lstm_seq as seq_lib

    cfg = MOBIRNN_LSTM
    B = 2
    p_width = max(cfg.input_dim, cfg.hidden)
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    for T in (128, 512, 1024, 2048):
        x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.input_dim))
        labels = jnp.zeros((B,), jnp.int32)
        n_fwd = count_kernel_dispatches(jax.make_jaxpr(
            lambda p, x: lstm.forward_fused_seq_q8(
                p, x, cfg, vmem_budget=STREAM_BUDGET))(params, x))
        n_train = count_train_dispatches(
            lambda p: lstm.loss_fn(
                p, x, labels, cfg,
                forward=lambda p, x, cfg: lstm.forward_fused_seq_q8(
                    p, x, cfg, vmem_budget=STREAM_BUDGET)),
            params)
        row(f"quant/dispatch_fused_seq_q8_T{T}", float(n_fwd),
            f"pallas_calls={n_fwd} (O(1) in T)")
        row(f"quant/train_dispatch_fused_seq_q8_T{T}", float(n_train),
            f"pallas_calls={n_train} (1 fwd + 1 bwd, O(1) in T)")
        for mode in ("fwd", "bwd"):
            f32 = seq_lib.choose_batch_block(
                B, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=STREAM_BUDGET, mode=mode)
            q8 = seq_lib.choose_batch_block(
                B, T, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=STREAM_BUDGET, mode=mode, quantized=True)
            parts = [f"f32_blocks={tuple(f32) if f32 else None}",
                     f"q8_blocks={tuple(q8) if q8 else None}"]
            if f32 is not None and q8 is not None:
                cf = lstm_seq_stream_costs(
                    T, cfg.n_layers, p_width, cfg.hidden, B, f32.block_b,
                    f32.time_chunk, mode=mode)
                cq = lstm_seq_stream_costs(
                    T, cfg.n_layers, p_width, cfg.hidden, B, q8.block_b,
                    q8.time_chunk, mode=mode, quantized=True)
                parts.append(f"streamed_f32={cf['hbm_bytes']:.0f}B")
                parts.append(f"streamed_q8={cq['hbm_bytes']:.0f}B"
                             f"({cq['hbm_bytes'] / cf['hbm_bytes']:.2f}x)")
                saved = float(cq["hbm_bytes"])
            else:
                saved = 0.0
            row(f"quant/budget_{mode}_T{T}", saved, ",".join(parts))


def bench_quant_smoke() -> None:
    """CI smoke (fast job): the q8 acceptance criteria, executed.

    Asserts (a) the quantization-aware table returns a strictly-no-finer
    tiling than f32 at the mobile-class budget, (b) the q8 plan is 1 fwd /
    2 train dispatches at a long T, (c) the executed kernels agree with the
    dequantize oracle within fp rounding and with the f32 sequential plan
    within the documented int8 error band, and (d) straight-through
    training grads are finite.
    """
    import numpy as np

    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.kernels import lstm_seq as seq_lib
    from repro.kernels import ref
    from repro.partitioning import split

    cfg = MOBIRNN_LSTM
    B, T = 2, 512
    p_width = max(cfg.input_dim, cfg.hidden)
    # no-finer-tiling acceptance across the fig2 T sweep, both modes
    for T_chk in (32, 128, 512, 1024, 2048):
        for mode in ("fwd", "bwd"):
            f32 = seq_lib.choose_batch_block(
                B, T_chk, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=STREAM_BUDGET, mode=mode)
            q8 = seq_lib.choose_batch_block(
                B, T_chk, cfg.n_layers, p_width, cfg.hidden,
                vmem_budget=STREAM_BUDGET, mode=mode, quantized=True)
            assert q8 is not None, (T_chk, mode)
            if f32 is not None:
                assert q8.block_b >= f32.block_b, (T_chk, mode, f32, q8)
                assert q8.time_chunk is None or (
                    f32.time_chunk is not None
                    and q8.time_chunk >= f32.time_chunk), (T_chk, mode,
                                                          f32, q8)

    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.input_dim))
    labels = jnp.zeros((B,), jnp.int32)

    def fwd(p, x, cfg):
        return lstm.forward_fused_seq_q8(p, x, cfg,
                                         vmem_budget=STREAM_BUDGET)

    n_fwd = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: fwd(p, x, cfg))(params, x))
    n_train = count_train_dispatches(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd), params)
    assert n_fwd == 1, f"q8 forward fell back: {n_fwd} dispatches"
    assert n_train == 2, f"q8 backward fell back: {n_train} dispatches"

    # executed kernels vs the dequantize oracle (fp-rounding band) ...
    values, _ = split(params)
    w_stack, b_stack, pw = seq_lib.stack_params(values["layers"], cfg.hidden)
    xp = seq_lib.pad_input(x, pw)
    wq, scales = ref.quantize_q8(w_stack)
    want_c, want_h = ref.lstm_seq_q8(wq, scales, b_stack, xp)
    got_c, got_h = seq_lib.lstm_seq_q8(w_stack, b_stack, xp)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               rtol=1e-4, atol=1e-5)
    # ... and the full plan vs the f32 sequential within the int8 band
    want = lstm.forward_sequential(params, x, cfg)
    got = fwd(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)
    _, grads = jax.value_and_grad(
        lambda p: lstm.loss_fn(p, x, labels, cfg, forward=fwd))(params)
    assert all(bool(jnp.all(jnp.isfinite(g)))
               for g in jax.tree.leaves(grads))
    row("quant_smoke/long_T_q8", float(T),
        f"fwd_dispatches={n_fwd},train_dispatches={n_train},"
        f"budget={STREAM_BUDGET}")


def bench_rwkv_rows() -> None:
    """rwkv/* rows: the rwkv6 family's chunked_scan plan holds its
    registered dispatch contract on the fig2 T sweep — 1 forward / 2 train
    Pallas dispatches at every T (the names contain "dispatch", so the
    regression guard fails CI on any silent oracle-replay fallback), plus
    the O(T/C) grid-step rows (count_pallas_grid_steps: BH * ceil(T/C),
    the sequential work a dispatch count cannot see) and the chunk the
    VMEM table picks at the mobile-class budget."""
    import math

    from repro.analysis import (count_kernel_dispatches,
                                count_pallas_grid_steps,
                                count_train_dispatches)
    from repro.core import plans
    from repro.kernels import wkv6 as wkv6_lib

    B, H, dk, dv, chunk = 2, 2, 8, 8, 32
    fam = plans.get_family("rwkv6")
    for T in (128, 512, 2048):
        case = plans.Case(f"bench_T{T}", (B, T, H, dk, dv, chunk))
        args, _ = fam.make_inputs(case, "float32")
        jx = jax.make_jaxpr(
            lambda *a: plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk))(
                *args)
        n_fwd = count_kernel_dispatches(jx)
        steps = count_pallas_grid_steps(jx)

        def loss(*a):
            out, s = plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk)
            return jnp.sum(out) + jnp.sum(s)

        n_train = count_train_dispatches(loss, *args)
        jx2 = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0,)))(*args)
        t_steps = count_pallas_grid_steps(jx2)
        want = B * H * math.ceil(T / chunk)
        row(f"rwkv/dispatch_chunked_scan_T{T}", float(n_fwd),
            f"pallas_calls={n_fwd} (O(1) in T)")
        row(f"rwkv/train_dispatch_chunked_scan_T{T}", float(n_train),
            f"pallas_calls={n_train} (1 traj fwd + 1 reverse sweep)")
        row(f"rwkv/grid_dispatch_steps_T{T}", float(steps),
            f"grid_steps={steps} (BH*ceil(T/C)={want})")
        row(f"rwkv/train_grid_dispatch_steps_T{T}", float(t_steps),
            f"grid_steps={t_steps} (2x fwd)")
        for mode in ("fwd", "bwd"):
            blocks = wkv6_lib.choose_blocks(
                1, T, dk, dv, target=chunk, vmem_budget=STREAM_BUDGET,
                mode=mode)
            row(f"rwkv/chunk_{mode}_T{T}",
                float(blocks.chunk if blocks else 0),
                f"chosen={tuple(blocks) if blocks else None}"
                f",budget={STREAM_BUDGET}")


def bench_rwkv_smoke() -> None:
    """CI smoke (fast job): the rwkv6 registry acceptance, executed.

    Asserts (a) the chunked_scan plan agrees with the stepwise oracle —
    values AND gradients — at a dividing and a NON-dividing T, (b) its
    dispatch counts match the PlanSpec (1 fwd / 2 train: no silent
    oracle-replay backward), (c) the chunk table is viable at the
    mobile-class budget and halves rather than vanishing under pressure,
    and (d) the double-buffered streamed windows are exact: a bh-tiled
    run (bh_tile > 1, non-dividing BH tail included) is bit-identical to
    the bh_tile=1 sweep, and the joint (chunk, bh_tile) table picks a
    real point at the mobile-class budget.
    """
    import functools

    import numpy as np

    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.core import plans
    from repro.kernels import wkv6 as wkv6_lib

    fam = plans.get_family("rwkv6")
    spec = fam.plans["chunked_scan"]
    for label, T in (("div", 64), ("nondiv", 61)):
        case = plans.Case(f"smoke_{label}", (2, T, 2, 8, 8, 16))
        inputs = fam.make_inputs(case, "float32")
        got = fam.apply("chunked_scan", inputs)
        want = fam.apply(fam.oracle, inputs)
        for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       **fam.tol("chunked_scan", "float32"))
        gg = fam.grads("chunked_scan", inputs)
        gw = fam.grads(fam.oracle, inputs)
        for a, w in zip(jax.tree.leaves(gg), jax.tree.leaves(gw)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(w),
                **fam.grad_tol("chunked_scan", "float32"))
        (args, chunk) = inputs
        n_fwd = count_kernel_dispatches(jax.make_jaxpr(
            lambda *a: plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk))(
                *args))

        def loss(*a):
            out, s = plans.RWKV_PLANS["chunked_scan"](*a, chunk=chunk)
            return jnp.sum(out) + jnp.sum(s)

        n_train = count_train_dispatches(loss, *args)
        assert n_fwd == spec.fwd_dispatches, \
            f"rwkv forward fell back at T={T}: {n_fwd} dispatches"
        assert n_train == spec.train_dispatches, \
            f"rwkv backward fell back at T={T}: {n_train} dispatches"

    assert plans.rwkv_viability(2048, 64, 64,
                                vmem_budget=STREAM_BUDGET)("chunked_scan")
    full = wkv6_lib.choose_blocks(1, 2048, 64, 64, target=32,
                                  vmem_budget=STREAM_BUDGET)
    assert full is not None
    tight = wkv6_lib.choose_blocks(
        1, 2048, 64, 64, target=32,
        vmem_budget=wkv6_lib.working_set_bytes(2048, 64, 64, full.chunk) - 1)
    assert tight is not None
    assert tight.chunk < full.chunk, (full, tight)   # halves, not vanishes
    row("rwkv_smoke/chunked_scan", float(full.chunk),
        f"fwd_dispatches=1,train_dispatches=2,chunk={full.chunk},"
        f"budget={STREAM_BUDGET}")

    # (d) streamed windows: bh-tiled sweep (non-dividing BH=B*H=3, tail
    # row masked against the shared f32 state scratch) is bit-identical
    # to the bh_tile=1 sweep of the same jitted kernel
    case = plans.Case("smoke_bh", (1, 23, 3, 8, 8, 8))    # BH=3, T=23
    (args, chunk) = fam.make_inputs(case, "float32")
    run = jax.jit(functools.partial(
        plans.RWKV_PLANS["chunked_scan"], chunk=chunk),
        static_argnames=("bh_tile",))
    base_out, base_s = run(*args, bh_tile=1)
    for bt in (2, 3):
        out, s = run(*args, bh_tile=bt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base_out))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(base_s))
    joint = wkv6_lib.choose_blocks(8, 2048, 64, 64, target=32,
                                   vmem_budget=STREAM_BUDGET)
    assert joint is not None and joint.bh_tile >= 1
    row("rwkv_smoke/streamed_windows", float(joint.bh_tile),
        f"bitwise_bh_tiles=(1,2,3),BH=3,T=23,joint={tuple(joint)},"
        f"budget={STREAM_BUDGET}")


def bench_mamba_rows() -> None:
    """mamba/* rows: the mamba family's fused_scan plan holds its
    registered dispatch contract on the fig2 T sweep — 1 forward / 2
    train Pallas dispatches at every T (the names contain "dispatch", so
    the regression guard fails CI on any silent scan-oracle fallback),
    plus the O(T/C) grid-step rows and the (block_b, chunk) the VMEM
    table picks at the mobile-class budget."""
    import math

    from repro.analysis import (count_kernel_dispatches,
                                count_pallas_grid_steps,
                                count_train_dispatches)
    from repro.core import plans
    from repro.kernels import mamba_scan as ms_lib

    B, di, ds, chunk, bm = 2, 8, 4, 32, 2
    fam = plans.get_family("mamba")
    for T in (128, 512, 2048):
        case = plans.Case(f"bench_T{T}", (B, T, di, ds, chunk, bm))
        args, _, _ = fam.make_inputs(case, "float32")
        jx = jax.make_jaxpr(
            lambda *a: plans.MAMBA_PLANS["fused_scan"](
                *a, chunk=chunk, block_b=bm))(*args)
        n_fwd = count_kernel_dispatches(jx)
        steps = count_pallas_grid_steps(jx)

        def loss(*a):
            y, h = plans.MAMBA_PLANS["fused_scan"](*a, chunk=chunk,
                                                   block_b=bm)
            return jnp.sum(y) + jnp.sum(h)

        n_train = count_train_dispatches(loss, *args)
        jx2 = jax.make_jaxpr(jax.value_and_grad(loss, argnums=(0,)))(*args)
        t_steps = count_pallas_grid_steps(jx2)
        want = math.ceil(B / bm) * math.ceil(T / chunk)
        row(f"mamba/dispatch_fused_scan_T{T}", float(n_fwd),
            f"pallas_calls={n_fwd} (O(1) in T)")
        row(f"mamba/train_dispatch_fused_scan_T{T}", float(n_train),
            f"pallas_calls={n_train} (1 traj fwd + 1 reverse sweep)")
        row(f"mamba/grid_dispatch_steps_T{T}", float(steps),
            f"grid_steps={steps} (ceil(B/bm)*ceil(T/C)={want})")
        row(f"mamba/train_grid_dispatch_steps_T{T}", float(t_steps),
            f"grid_steps={t_steps} (2x fwd)")
        for mode in ("fwd", "bwd"):
            blocks = ms_lib.choose_blocks(
                B, T, di, ds, vmem_budget=STREAM_BUDGET, mode=mode)
            row(f"mamba/blocks_{mode}_T{T}",
                float(blocks.chunk if blocks else 0),
                f"chosen={tuple(blocks) if blocks else None}"
                f",budget={STREAM_BUDGET}")


def bench_mamba_smoke() -> None:
    """CI smoke (fast job): the mamba registry acceptance, executed.

    Asserts (a) the fused_scan plan agrees with the lax.scan oracle —
    values AND gradients — at a dividing and a NON-dividing T (identity
    zero-pad) and a non-dividing batch tile, (b) its dispatch counts
    match the PlanSpec (1 fwd / 2 train: no silent scan-replay
    backward), and (c) the joint (block_b, chunk) table is viable at the
    mobile-class budget and refines rather than vanishing under pressure.
    """
    import numpy as np

    from repro.analysis import count_kernel_dispatches, count_train_dispatches
    from repro.core import plans
    from repro.kernels import mamba_scan as ms_lib

    fam = plans.get_family("mamba")
    spec = fam.plans["fused_scan"]
    for label, (B, T, bm) in (("div", (2, 64, 2)), ("nondiv", (3, 61, 2))):
        case = plans.Case(f"smoke_{label}", (B, T, 8, 4, 16, bm))
        inputs = fam.make_inputs(case, "float32")
        got = fam.apply("fused_scan", inputs)
        want = fam.apply(fam.oracle, inputs)
        for a, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       **fam.tol("fused_scan", "float32"))
        gg = fam.grads("fused_scan", inputs)
        gw = fam.grads(fam.oracle, inputs)
        for a, w in zip(jax.tree.leaves(gg), jax.tree.leaves(gw)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(w),
                **fam.grad_tol("fused_scan", "float32"))
        (args, chunk, block_b) = inputs
        n_fwd = count_kernel_dispatches(jax.make_jaxpr(
            lambda *a: plans.MAMBA_PLANS["fused_scan"](
                *a, chunk=chunk, block_b=block_b))(*args))

        def loss(*a):
            y, h = plans.MAMBA_PLANS["fused_scan"](*a, chunk=chunk,
                                                   block_b=block_b)
            return jnp.sum(y) + jnp.sum(h)

        n_train = count_train_dispatches(loss, *args)
        assert n_fwd == spec.fwd_dispatches, \
            f"mamba forward fell back at T={T}: {n_fwd} dispatches"
        assert n_train == spec.train_dispatches, \
            f"mamba backward fell back at T={T}: {n_train} dispatches"

    assert plans.mamba_viability(4, 2048, 64, 16,
                                 vmem_budget=STREAM_BUDGET)("fused_scan")
    full = ms_lib.choose_blocks(4, 2048, 64, 16,
                                vmem_budget=STREAM_BUDGET)
    assert full is not None
    ws = ms_lib.working_set_bytes(2048, 64, 16, full.block_b, full.chunk)
    tight = ms_lib.choose_blocks(4, 2048, 64, 16, vmem_budget=ws - 1)
    assert tight is not None
    assert tuple(tight) != tuple(full), (full, tight)  # refines, not gone
    row("mamba_smoke/fused_scan", float(full.chunk),
        f"fwd_dispatches=1,train_dispatches=2,blocks={tuple(full)},"
        f"budget={STREAM_BUDGET}")


def bench_fig4_speedup() -> None:
    cfg = MOBIRNN_LSTM
    in_dim = cfg.input_dim + cfg.hidden
    best = fz.best_cols_per_unit(fz.MOBILE_GPU, in_dim, 4 * cfg.hidden)
    t_gpu = fz.factorize_gate(fz.MOBILE_GPU, in_dim, 4 * cfg.hidden, best)
    t_cpu = fz.factorize_gate(fz.MOBILE_CPU1, in_dim, 4 * cfg.hidden,
                              4 * cfg.hidden)
    row("fig4/model_mobirnn_speedup", t_gpu * 1e6,
        f"cpu/gpu={t_cpu / t_gpu:.2f}x (paper: 3.93x on Nexus5)")


def bench_fig5_complexity() -> None:
    for hidden in (32, 64, 128, 256):
        for layers in (1, 2, 3):
            cfg = MOBIRNN_LSTM.with_complexity(hidden, layers)
            in_dim = cfg.input_dim + hidden
            best = fz.best_cols_per_unit(fz.MOBILE_GPU, in_dim, 4 * hidden)
            t_gpu = layers * fz.factorize_gate(fz.MOBILE_GPU, in_dim,
                                               4 * hidden, best)
            t_cpu = layers * fz.factorize_gate(fz.MOBILE_CPU1, in_dim,
                                               4 * hidden, 4 * hidden)
            row(f"fig5/model_h{hidden}_l{layers}", t_gpu * 1e6,
                f"speedup={t_cpu / t_gpu:.2f}x")


def bench_fig6_multithread() -> None:
    cfg = MOBIRNN_LSTM
    in_dim = cfg.input_dim + cfg.hidden
    best_gpu = fz.best_cols_per_unit(fz.MOBILE_GPU, in_dim, 4 * cfg.hidden)
    t_gpu = fz.factorize_gate(fz.MOBILE_GPU, in_dim, 4 * cfg.hidden,
                              best_gpu)
    best_cpu = fz.best_cols_per_unit(fz.MOBILE_CPU4, in_dim, 4 * cfg.hidden)
    t_mt = fz.factorize_gate(fz.MOBILE_CPU4, in_dim, 4 * cfg.hidden,
                             best_cpu)
    row("fig6/model_multithread_cpu", t_mt * 1e6,
        f"mt_cpu_gets={t_gpu / t_mt:.0%} of gpu perf (paper: >=70%)")


def bench_train_step() -> None:
    """Train-step wall time per execution plan — the training story the
    fused backward kernel unlocks: with ``fused_seq`` the whole
    ``value_and_grad`` is 2 Pallas dispatches instead of an O(T*L) oracle
    replay.  Viability of the fused plan's BACKWARD working set is checked
    via plan_viability(train=True) and noted in the derived column."""
    from repro.optim import AdamW

    cfg = MOBIRNN_LSTM.with_complexity(32, 2)
    B, T = 8, 32
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.input_dim))
    labels = jnp.zeros((B,), jnp.int32)
    opt = AdamW(lr=1e-3)
    viable = lstm.plan_viability(cfg, B, T, train=True)
    base = None
    for name, fwd in lstm.FORWARD_PLANS.items():
        state = opt.init(params)

        @jax.jit
        def step(p, s, fwd=fwd):
            loss, grads = jax.value_and_grad(lstm.loss_fn)(
                p, x, labels, cfg, forward=fwd)
            p, s, _ = opt.update(grads, s, p)
            return p, s, loss

        t = timeit(step, params, state, repeats=2)
        base = base or t
        note = f"speedup_vs_sequential={base / t:.2f}x"
        if name in ("fused_seq", "fused_seq_q8"):
            note += f",bwd_viable={viable(name)}"
        row(f"train/step_{name}_B{B}_T{T}", t, note)


def bench_fig7_load() -> None:
    cfg = MOBIRNN_LSTM
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, cfg.seq_len,
                                                  cfg.input_dim))
    accel = jax.jit(lambda p, x: lstm.forward_wavefront(p, x, cfg))
    accel_seq = jax.jit(lambda p, x: lstm.forward_fused_seq(p, x, cfg))
    accel_seq_q8 = jax.jit(lambda p, x: lstm.forward_fused_seq_q8(p, x, cfg))
    cpu = jax.jit(lambda p, x: lstm.forward_sequential(p, x, cfg))
    sensor = SyntheticLoadSensor(0.0)
    # VMEM-model viability: never calibrate/choose the sequence-resident
    # plan when choose_batch_block says it cannot fit (it would silently
    # benchmark its fused_cell fallback under the wrong name).  This is the
    # INFERENCE dispatch bench, so the forward working set (train=False) is
    # the right gate; a train-time scheduler passes train=True to size the
    # ~3x backward working set instead (see bench_train_step).  The q8 plan
    # is gated by the quantization-aware table (4x smaller weight term), so
    # the per-tick choice keeps a fused option under budgets that filter
    # the f32 plan out.
    sched = Scheduler(sensor, viable=lstm.plan_viability(
        cfg, 1, cfg.seq_len, seq_plan_names=("accel_seq",),
        q8_plan_names=("accel_seq_q8",), train=False))
    sched.register(Plan("accel", accel, shared=True, sensitivity=1.0))
    sched.register(Plan("accel_seq", accel_seq, shared=True,
                        sensitivity=1.0))
    sched.register(Plan("accel_seq_q8", accel_seq_q8, shared=True,
                        sensitivity=1.0))
    sched.register(Plan("cpu", cpu, shared=False))
    sched.calibrate(params, x)
    for load in (0.1, 0.3, 0.5, 0.7, 0.9):
        sensor.value = load
        d = sched.choose()
        pred = d.predicted_s[d.plan]
        row(f"fig7/load_{load:.1f}", pred * 1e6,
            f"dispatch={d.plan}")
    crossings = [d.plan for d in sched.decisions]
    row("fig7/crossover", 0.0, f"sequence={'>'.join(crossings)}")


# ---------------------------------------------------------------------------
def bench_serving() -> None:
    """Wave vs slot engine on a RAGGED workload: mixed prompt lengths and an
    8x ``max_new_tokens`` spread.  The wave engine pads every request in a
    wave to the longest prompt and the longest token budget, so short
    requests burn dead ticks; the slot engine retires each lane the step it
    finishes and admits the next queued request — same model, same plans,
    higher tokens/sec.  Also asserts the slot engine's zero-allocation
    invariant (StatePool stats) after warmup."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models import registry
    from repro.partitioning import split
    from repro.serving import Engine, EngineConfig, Request, SlotEngine

    cfg = dataclasses.replace(
        get_arch("qwen2-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=128, vocab=256)
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))

    rng = np.random.default_rng(0)
    lens = [4, 12, 6, 16, 8, 4, 12, 6, 16, 8, 4, 12]
    news = [2, 32, 4, 24, 32, 2, 24, 4, 32, 2, 4, 24]    # 16x spread
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]

    def reqs():
        return [Request(i, p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, news))]

    n_tok = sum(news)
    wave = Engine(model, params, config=EngineConfig(
        n_slots=4, max_seq=64, pool_capacity=1))
    wave.serve(reqs())                                   # compile/warmup
    t0 = time.perf_counter()
    wave.serve(reqs())
    t_wave = time.perf_counter() - t0
    row("serving/wave_ragged", t_wave * 1e6 / n_tok,
        f"tok_per_s={n_tok / t_wave:.1f}")

    slot = SlotEngine(model, params, config=EngineConfig(
        n_slots=4, max_seq=64, queue_capacity=8))
    slot.serve(reqs())                                   # compile/warmup
    import gc

    gc.collect()
    live0 = len(jax.live_arrays())
    t0 = time.perf_counter()
    slot.serve(reqs())
    t_slot = time.perf_counter() - t0
    gc.collect()
    live1 = len(jax.live_arrays())
    # the REAL zero-allocation invariant: a warm serve leaves the live
    # device-buffer population unchanged (pool buffers reset in place via
    # donation; pool stats corroborate that none were rebuilt)
    assert live1 <= live0, (live0, live1)
    assert (slot.pool.stats.buffers_built,
            slot._scratch_pool.stats.buffers_built) == (1, 1), \
        "slot engine rebuilt pool buffers on the serving path"
    row("serving/slot_ragged", t_slot * 1e6 / n_tok,
        f"tok_per_s={n_tok / t_slot:.1f},speedup_vs_wave="
        f"{t_wave / t_slot:.2f}x,live_buffers_delta={live1 - live0}")

    # per-request latency distributions from the engine's always-on obs
    # metrics (accumulated over warmup + timed serves): TTFT is
    # admit->first-token-on-host, TBT the per-lane gap between decode
    # tokens — the serving numbers MobiRNN-style tuning should move
    ttft = slot.metrics.histogram("serving/ttft_s").summary()
    tbt = slot.metrics.histogram("serving/tbt_s").summary()
    row("serving/slot_ttft_p50", ttft["p50"] * 1e6,
        f"p99_us={ttft['p99'] * 1e6:.1f},n={ttft['count']}")
    row("serving/slot_tbt_p50", tbt["p50"] * 1e6,
        f"p99_us={tbt['p99'] * 1e6:.1f},n={tbt['count']}")

    # TTFT under contention (ISSUE 10 headline): short requests queued
    # behind long-prompt adversaries.  Whole-prompt admission stalls the
    # tick loop for each adversary's full prefill; chunked admission
    # interleaves, bounding any single stall at ~one chunk.  NOTE the
    # wall-clock rows track a tradeoff, not a one-way win: on this tiny
    # model a whole 48-token prefill is ONE sub-ms dispatch, so the
    # per-chunk dispatch overhead chunking adds can exceed the stall it
    # removes — the granularity bound itself is asserted structurally in
    # --prefill-smoke, where it is model-size-independent.
    adv_lens = [48, 4, 48, 4, 48, 4, 4, 4]               # adversary, short, ...
    adv_news = [4, 8, 4, 8, 4, 8, 8, 8]
    adv_prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
                   for l in adv_lens]

    def adv_reqs():
        return [Request(i, p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(adv_prompts, adv_news))]

    short_uids = {i for i, l in enumerate(adv_lens) if l == 4}
    for label, config in (
            ("whole", EngineConfig(n_slots=2, max_seq=64, queue_capacity=8)),
            ("chunked", EngineConfig(n_slots=2, max_seq=64, queue_capacity=8,
                                     prefill_chunk_len=8, prefill_lanes=2))):
        eng = SlotEngine(model, params, config=config)
        eng.serve(adv_reqs())                            # compile/warmup
        first_tok: dict[int, float] = {}

        def on_token(ev, first_tok=first_tok):
            if ev.token is not None and ev.uid not in first_tok:
                first_tok[ev.uid] = time.perf_counter()

        t0 = time.perf_counter()
        eng.serve(adv_reqs(), on_token=on_token)
        # submit-to-first-token for the SHORT requests: includes the queue
        # wait behind adversary prefills, the number chunking improves
        short_ttfts = sorted(first_tok[u] - t0 for u in short_uids)
        p50 = short_ttfts[len(short_ttfts) // 2]
        row(f"serving/adversary_short_ttft_p50_{label}", p50 * 1e6,
            f"p99_us={short_ttfts[-1] * 1e6:.1f},n={len(short_ttfts)},"
            f"adversary_prompt=48")


def bench_prefill_smoke() -> None:
    """CI smoke (fast job): the ISSUE 10 chunked-prefill acceptance,
    executed.

    Asserts (a) chunked admission is greedy-token-identical to
    whole-prompt admission on a tiny dense AND a tiny rwkv model; (b) the
    compiled-shape contract — exactly ONE prefill-chunk executable per
    distinct segment length used (the schedule's shape set is {C} plus
    descending powers of two for the remainder); (c) the TTFT-adversary
    headline, structurally: short requests queued alongside a long-prompt
    adversary produce their first tokens BEFORE the adversary's first —
    chunked admission stalls the tick loop by at most one chunk, never an
    entire foreign prefill; and (d) the zero-allocation invariant through
    chunked admission (scratch pool built once at lane capacity, no lane
    leaks).
    """
    import dataclasses

    from repro.configs import get_arch
    from repro.models import registry
    from repro.obs import trace as trace_lib
    from repro.partitioning import split
    from repro.serving import (EngineConfig, Request, SlotEngine,
                               chunk_schedule)

    rng = np.random.default_rng(0)
    lens, news = [5, 13, 3, 9], [4, 3, 5, 2]
    dense = None
    for arch in ("qwen2-0.5b", "rwkv6-3b"):
        cfg = get_arch(arch).reduced()
        if arch == "qwen2-0.5b":
            cfg = dataclasses.replace(
                cfg, n_layers=2, d_model=64, n_heads=2, n_kv_heads=1,
                head_dim=16, d_ff=128, vocab=128)
        model = registry.build(cfg)
        params, _ = split(model.init(jax.random.PRNGKey(0)))
        if arch == "qwen2-0.5b":
            dense = (cfg, model, params)
        prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
                   for l in lens]

        def reqs():
            return [Request(i, p, max_new_tokens=n)
                    for i, (p, n) in enumerate(zip(prompts, news))]

        whole = SlotEngine(model, params, config=EngineConfig(
            n_slots=2, max_seq=32)).serve(reqs())
        eng = SlotEngine(model, params, config=EngineConfig(
            n_slots=2, max_seq=32, prefill_chunk_len=4, prefill_lanes=2))
        chunked = eng.serve(reqs())
        for w, g in zip(whole, chunked):
            assert np.array_equal(w.tokens, g.tokens), \
                f"{arch} uid {w.uid}: chunked != whole-prompt tokens"
        segs = set()
        for l in lens:
            segs.update(chunk_schedule(l, 4))
        n_exec = eng._prefill_chunk._cache_size()
        assert n_exec == len(segs), \
            f"{arch}: {n_exec} prefill executables for shapes {sorted(segs)}"
        sp = eng._scratch_pool.stats
        assert sp.buffers_built == sp.capacity == 2 and sp.outstanding == 0, \
            f"{arch}: scratch pool leaked through chunked admission: {sp}"
        row(f"prefill_smoke/{arch}", float(n_exec),
            f"chunk_shapes={sorted(segs)},identity=ok,"
            f"buffers_built={sp.buffers_built}")

    # (c) TTFT under an adversary, deterministic/structural: a 24-token
    # prompt (6 chunks of 4) competes with short 4-token prompts.  Every
    # short request's FIRST token must land before the adversary's first
    # — whole-prompt admission would stall the loop for the full foreign
    # prefill instead.  The trace corroborates: one serve/prefill_chunk
    # per scheduled segment.
    cfg, model, params = dense
    short_prompts = [rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
                     for _ in range(2)]
    adversary = rng.integers(0, cfg.vocab, (24,)).astype(np.int32)

    def adv_reqs():
        return [Request(0, short_prompts[0], max_new_tokens=8),
                Request(1, adversary, max_new_tokens=2),
                Request(2, short_prompts[1], max_new_tokens=8)]

    eng = SlotEngine(model, params, config=EngineConfig(
        n_slots=3, max_seq=32, queue_capacity=4,
        prefill_chunk_len=4, prefill_lanes=2))
    sink = trace_lib.ListSink()
    old = trace_lib.set_tracer(trace_lib.Tracer(sink))
    try:
        events = []
        eng.serve(adv_reqs(), on_token=events.append)
    finally:
        trace_lib.set_tracer(old)
    uids = [ev.uid for ev in events if ev.token is not None]
    first_adv = uids.index(1)
    for short_uid in (0, 2):
        assert short_uid in uids[:first_adv], \
            f"short request {short_uid} starved behind the adversary prefill"
    n_chunk_events = sum(r["name"] == "serve/prefill_chunk"
                         for r in sink.records)
    want_chunks = (len(chunk_schedule(24, 4))
                   + 2 * len(chunk_schedule(4, 4)))
    assert n_chunk_events == want_chunks, (n_chunk_events, want_chunks)
    short_before = uids[:first_adv].count(0) + uids[:first_adv].count(2)
    row("prefill_smoke/adversary", float(short_before),
        f"short_tokens_before_adversary_first={short_before},"
        f"prefill_chunk_events={n_chunk_events}")


def bench_obs_smoke(trace_path: str = "BENCH_ci_obs_trace.jsonl",
                    profile_path: str = "BENCH_ci_obs_profile.json") -> None:
    """CI smoke (fast job): the ISSUE 7 observability acceptance, executed.

    Asserts (a) a traced SlotEngine run produces well-formed JSONL with
    per-tick spans (plan + tick latency), per-request TTFT admit events,
    nested sched/choose decisions, and the end-of-stream metrics summary
    (queue depth gauge, deadline-miss counter); (b) tracing changes NO
    tokens and keeps the zero-allocation invariant; (c) the measured
    profiler sweeps >= 2 viable tiling points for ALL THREE registered
    families (lstm's (block_b, time_chunk) surface, rwkv6's widened
    (bh_tile, chunk) surface, mamba's (block_b, chunk) surface), the
    profile round-trips through save/load, ``Scheduler.calibrate`` seeds
    base latencies from it, and the model-vs-measured report carries a
    finite ratio per point.  The trace and profile files are uploaded as
    CI artifacts next to the BENCH_ci_*.json rows.
    """
    import dataclasses

    from repro.configs import get_arch
    from repro.models import registry
    from repro.obs import profile as profile_lib
    from repro.obs import trace as trace_lib
    from repro.partitioning import split
    from repro.serving import EngineConfig, Request, SlotEngine

    cfg = dataclasses.replace(
        get_arch("qwen2-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=128, vocab=128)
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in (5, 3, 7, 4, 6, 3)]
    news = [6, 4, 5, 6, 3, 4]

    def reqs():
        return [Request(i, p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, news))]

    # --- traced vs untraced serving: token-identical, zero-alloc --------
    plain = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=32))
    base = {r.uid: r.tokens.tolist() for r in plain.serve(reqs())}
    old = trace_lib.set_tracer(trace_lib.Tracer(trace_lib.JsonlSink(
        trace_path)))
    try:
        traced_eng = SlotEngine(model, params, config=EngineConfig(
            n_slots=2, max_seq=32))
        traced = {r.uid: r.tokens.tolist()
                  for r in traced_eng.serve(reqs())}
    finally:
        trace_lib.get_tracer().close()
        trace_lib.set_tracer(old)
    assert traced == base, "tracing changed greedy outputs"
    assert traced_eng.pool.stats.buffers_built == 1, \
        "traced serving run rebuilt pool buffers"

    events = trace_lib.read_jsonl(trace_path)
    assert events, "empty trace"
    ticks = [e for e in events if e["name"] == "serve/tick"]
    admits = [e for e in events if e["name"] == "serve/admit"]
    chooses = [e for e in events if e["name"] == "sched/choose"]
    summaries = [e for e in events if e["name"] == "serve/metrics"]
    assert ticks and all("plan" in e["attrs"] and "tick_s" in e["attrs"]
                         for e in ticks), "malformed serve/tick spans"
    assert len(admits) == len(news) and all(
        e["attrs"]["ttft_s"] > 0 for e in admits), "missing TTFT events"
    tick_ids = {e["span"] for e in ticks}
    assert chooses and all(e["parent"] in tick_ids for e in chooses), \
        "sched/choose not nested under serve/tick"
    assert summaries and "serving/deadline_miss" in \
        summaries[-1]["attrs"]["counters"], "missing metrics summary"
    row("obs_smoke/trace", float(len(events)),
        f"ticks={len(ticks)},admits={len(admits)},file={trace_path}")

    # --- measured profiler: all three families, save/load, calibrate ----
    prof = profile_lib.profile_families(
        ("lstm", "rwkv6", "mamba"), vmem_budget=STREAM_BUDGET, repeats=1,
        warmup=1, max_points=2,
        hook_kwargs={"lstm": {"batch": 2, "seq_len": 16},
                     "rwkv6": {"seq_len": 32, "n_bh": 2, "target": 8},
                     "mamba": {"batch": 2, "seq_len": 16, "d_inner": 8,
                               "d_state": 4}})
    for fam in ("lstm", "rwkv6", "mamba"):
        n = sum(p.family == fam for p in prof.points)
        assert n >= 2, f"profiler swept {n} < 2 points for {fam}"
    # the widened rwkv6 surface exposes the bh-tile axis, not just chunk
    rwkv_tiles = {p.point.get("bh_tile") for p in prof.points
                  if p.family == "rwkv6"}
    assert len(rwkv_tiles) >= 2, \
        f"rwkv6 profile points collapsed to one bh_tile: {rwkv_tiles}"
    prof.save(profile_path)
    prof2 = profile_lib.DeviceProfile.load(profile_path)
    assert prof2.to_json() == prof.to_json(), "profile did not round-trip"

    sched = Scheduler(SyntheticLoadSensor(0.0))
    sched.register(Plan("fused_seq", lambda: None))
    sched.register(Plan("chunked_scan", lambda: None))
    sched.register(Plan("fused_scan", lambda: None))
    sched.calibrate(profile=prof2.best_latencies())
    assert all(np.isfinite(p.base_latency_s)
               for p in sched.plans.values()), "profile seeding failed"

    report = profile_lib.model_vs_measured(prof2, threshold=3.0)
    assert len(report) == len(prof.points) and all(
        r["finite"] for r in report), "non-finite model-vs-measured ratio"
    worst = max(r["ratio"] for r in report)
    row("obs_smoke/profile", float(len(prof.points)),
        f"families=3,key={prof.key},max_ratio={worst:.3g},"
        f"file={profile_path}")


def bench_chaos_smoke(trace_path: str = "BENCH_ci_chaos_trace.jsonl",
                      faults_path: str = "BENCH_ci_chaos_faults.json"
                      ) -> None:
    """CI smoke (fast job): the ISSUE 9 fault-tolerance acceptance, executed.

    Drives a seeded FaultPlan (a NaN-poisoned lane, a failed prefill, a
    3-tick slow burst) through a SlotEngine with a retry budget and a
    degradation ladder, and asserts (a) every request terminates with a
    finish_reason from the closed set; (b) every request that finishes
    'length' — including the quarantined-then-retried and the
    prefill-faulted ones — carries tokens bit-identical to a fault-free
    run; (c) the zero-allocation invariant holds through quarantine and
    re-admission (pool and scratch buffers_built stay at capacity); (d)
    the watchdog's plan downshift and the shed decisions are visible in
    the JSONL trace (serve/fault, serve/quarantine, serve/shed,
    sched/degrade; post-degrade sched/choose picks the fallback plan).
    The fault schedule and the trace are written next to the other
    BENCH_ci_* artifacts so any failure replays exactly.
    """
    import dataclasses

    from repro.configs import get_arch
    from repro.models import registry
    from repro.obs import trace as trace_lib
    from repro.partitioning import split
    from repro.serving import (FINISH_REASONS, EngineConfig, FaultPlan,
                               FinishReason, LanePoison, PrefillFault,
                               Request, SlotEngine, SlowTick)
    from repro import steps as steps_lib

    cfg = dataclasses.replace(
        get_arch("qwen2-0.5b").reduced(), n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=1, head_dim=16, d_ff=128, vocab=128)
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(0)
    lens, news = (5, 9, 3, 7, 4, 6), (12, 12, 6, 12, 4, 4)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]

    def reqs(deadline=None):
        # uids 4-5 carry deadlines ~1000s out: trivially meetable on a
        # healthy engine, provably unmeetable once the slow burst drives
        # the tick EMA to ~1e6 s — the shed sweep's targets
        return [Request(i, p, max_new_tokens=n,
                        deadline_s=(None if deadline is None or i < 4
                                    else deadline + i))
                for i, (p, n) in enumerate(zip(prompts, news))]

    faults = FaultPlan(seed=0, faults=(
        LanePoison(tick=1, lane=0),
        PrefillFault(uid=2),
        SlowTick(tick=4, extra_s=1e6),
        SlowTick(tick=5, extra_s=1e6),
        SlowTick(tick=6, extra_s=1e6)))
    faults.save(faults_path)

    # fault-free reference: what every 'length' finisher must reproduce
    base_eng = SlotEngine(model, params, config=EngineConfig(
        n_slots=2, max_seq=64, queue_capacity=4))
    base = {r.uid: r.tokens.tolist()
            for r in base_eng.serve(reqs(base_eng.clock() + 1000.0))}

    old = trace_lib.set_tracer(trace_lib.Tracer(trace_lib.JsonlSink(
        trace_path)))
    try:
        eng = SlotEngine(
            model, params,
            config=EngineConfig(
                n_slots=2, max_seq=64, queue_capacity=4,
                faults=faults, retry_budget=1, tick_slo_s=50.0,
                slo_breach_ticks=3, slo_recover_ticks=99,
                ladder=["decode/base"]),
            extra_plans={"decode/fallback":
                         lambda p, c, b: steps_lib.decode_step(cfg, p, c, b)})
        chaos = {r.uid: r for r in eng.serve(reqs(eng.clock() + 1000.0))}
    finally:
        trace_lib.get_tracer().close()
        trace_lib.set_tracer(old)

    # (a) all terminate, closed set; (b) healthy-lane bit-identity
    assert set(chaos) == set(range(6)), sorted(chaos)
    assert all(r.finish_reason in FINISH_REASONS for r in chaos.values())
    reasons = {u: r.finish_reason for u, r in chaos.items()}
    for uid in (0, 1, 2, 3):
        assert reasons[uid] == FinishReason.LENGTH, reasons
        assert chaos[uid].tokens.tolist() == base[uid], \
            f"uid {uid} diverged from the fault-free run"
    for uid in (4, 5):
        assert reasons[uid] == FinishReason.SHED, reasons
    # (c) zero-alloc through quarantine + re-admission
    assert eng.pool.stats.buffers_built == 1
    assert eng._scratch_pool.stats.buffers_built == 1
    q = eng.metrics.counter("serving/quarantined").value
    rt = eng.metrics.counter("serving/retries").value
    sh = eng.metrics.counter("serving/shed").value
    assert q >= 1 and rt >= 1 and sh >= 1, (q, rt, sh)
    assert eng.scheduler.level == 1     # degraded, recovery disabled

    # (d) the chaos story is visible in the trace
    events = trace_lib.read_jsonl(trace_path)
    kinds = {e["attrs"]["kind"] for e in events
             if e["name"] == "serve/fault"}
    assert {"poison", "prefill", "slow"} <= kinds, kinds
    assert any(e["name"] == "serve/quarantine" for e in events)
    assert any(e["name"] == "serve/shed" for e in events)
    degrades = [e for e in events if e["name"] == "sched/degrade"]
    assert degrades, "watchdog never stepped the ladder"
    post = [e["attrs"]["plan"] for e in events
            if e["name"] == "sched/choose" and e["seq"] > degrades[0]["seq"]]
    assert post and set(post) == {"decode/fallback"}, \
        f"no downshift after sched/degrade: {post[:5]}"
    row("chaos_smoke/seeded_faults", float(len(events)),
        f"quarantined={q},retries={rt},shed={sh},reasons="
        f"{'|'.join(sorted(set(reasons.values())))},files={faults_path}"
        f"+{trace_path}")


def bench_kernels() -> None:
    from repro.kernels import ops, ref

    B, D, H = 8, 64, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w = jax.random.normal(ks[0], (D + H, 4 * H)) * 0.1
    b = jnp.zeros((4 * H,))
    x, c, h = (jax.random.normal(k, (B, d)) for k, d in
               zip(ks[1:], (D, H, H)))
    row("kernel/lstm_cell_interpret",
        timeit(lambda: ops.lstm_cell(w, b, x, c, h), repeats=3), "")
    row("kernel/lstm_cell_ref",
        timeit(lambda: jax.jit(ref.lstm_cell)(w, b, x, c, h)), "oracle")

    BH, T, dk = 4, 128, 32
    r, k2, v = (jax.random.normal(kk, (BH, T, dk)) for kk in ks[:3])
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, T, dk)))
    u = jax.random.normal(ks[4], (BH, dk))
    s0 = jnp.zeros((BH, dk, dk))
    row("kernel/wkv6_interpret",
        timeit(lambda: ops.wkv6(r, k2, v, logw, u, s0, chunk=32),
               repeats=2), "")

    B2, Hq, Hkv, S, dh = 4, 8, 2, 512, 64
    q = jax.random.normal(ks[0], (B2, Hq, dh))
    kc = jax.random.normal(ks[1], (B2, S, Hkv, dh))
    vc = jax.random.normal(ks[2], (B2, S, Hkv, dh))
    lens = jnp.full((B2,), S, jnp.int32)
    row("kernel/decode_attn_interpret",
        timeit(lambda: ops.decode_attn(q, kc, vc, lens), repeats=2), "")


def bench_wkv_chunks() -> None:
    """Empirical work-unit coarseness curve: the paper's Fig 2/3 effect
    measured on real hardware for the rwkv scan (chunk = unit size)."""
    from repro.models.rwkv import wkv_chunked

    B, S, Hh, dk = 2, 256, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(kk, (B, S, Hh, dk)) for kk in ks[:3])
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, Hh, dk)))
    u = jax.random.normal(ks[4], (Hh, dk))
    s0 = jnp.zeros((B, Hh, dk, dk))
    base = None
    for chunk in (1, 4, 16, 64):
        fn = jax.jit(lambda r, k, v, w, u, s, c=chunk: wkv_chunked(
            r, k, v, w, u, s, c))
        t = timeit(fn, r, k, v, logw, u, s0, repeats=3)
        base = base or t
        row(f"scan/wkv_chunk_{chunk}", t, f"speedup_vs_chunk1="
            f"{base / t:.2f}x")


def bench_moe_capacity() -> None:
    import dataclasses

    from repro.configs import get_arch
    from repro.models import moe as moe_lib
    from repro.partitioning import split

    base_cfg = get_arch("olmoe-1b-7b").reduced()
    x = jax.random.normal(jax.random.PRNGKey(1), (256, base_cfg.d_model))
    for cf in (0.5, 1.0, 1.25, 2.0):
        cfg = dataclasses.replace(
            base_cfg, moe=dataclasses.replace(base_cfg.moe,
                                              capacity_factor=cf))
        p, _ = split(moe_lib.init_moe(jax.random.PRNGKey(0), cfg,
                                      jnp.float32))
        fn = jax.jit(lambda p, x, c=cfg: moe_lib.apply_moe(p, x, c))
        t = timeit(fn, p, x, repeats=3)
        _, aux = fn(p, x)
        row(f"moe/capacity_{cf}", t,
            f"drop_frac={float(aux['moe_drop_frac']):.3f}")


def write_json(path: str) -> None:
    """Machine-readable benchmark rows (fig2 fwd+bwd dispatch counts,
    train-step wall time per plan, serving tokens/sec live in `derived`) so
    the perf trajectory is diffable across PRs."""
    import json

    with open(path, "w") as fh:
        json.dump([{"name": n, "us_per_call": us, "derived": d}
                   for n, us, d in ROWS], fh, indent=1)
    print(f"wrote {len(ROWS)} rows to {path}")


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serving", action="store_true",
                    help="run only the serving throughput benchmark "
                         "(wave vs slot engine; the CI smoke invocation)")
    ap.add_argument("--train", action="store_true",
                    help="run only the per-plan train-step benchmark")
    ap.add_argument("--stream-smoke", action="store_true",
                    help="run only the long-T streaming smoke (asserts the "
                         "fused plan does NOT fall back past the "
                         "whole-T-resident budget; the CI fast-job "
                         "invocation)")
    ap.add_argument("--quant-smoke", action="store_true",
                    help="run only the int8-plan smoke (asserts 1 fwd / 2 "
                         "train dispatches for fused_seq_q8, oracle "
                         "agreement within the int8 error band, and the "
                         "no-finer q8 tiling at the mobile budget; the CI "
                         "fast-job invocation)")
    ap.add_argument("--rwkv-smoke", action="store_true",
                    help="run only the rwkv6 chunked-scan smoke (asserts "
                         "registry equivalence vs the stepwise oracle — "
                         "values and gradients, dividing and non-dividing "
                         "T — plus the 1 fwd / 2 train dispatch contract "
                         "and chunk-table viability at the mobile budget; "
                         "the CI fast-job invocation)")
    ap.add_argument("--mamba-smoke", action="store_true",
                    help="run only the mamba fused-scan smoke (asserts "
                         "registry equivalence vs the lax.scan oracle — "
                         "values and gradients, dividing and non-dividing "
                         "T and batch tile — plus the 1 fwd / 2 train "
                         "dispatch contract and (block_b, chunk) table "
                         "viability at the mobile budget; the CI fast-job "
                         "invocation)")
    ap.add_argument("--obs-smoke", action="store_true",
                    help="run only the observability smoke (traced serving "
                         "run: per-tick spans, TTFT, token identity, "
                         "zero-alloc; measured 2-point profiler sweep for "
                         "both families with save/load round-trip, "
                         "calibrate seeding and a finite model-vs-measured "
                         "ratio; the CI fast-job invocation — writes "
                         "BENCH_ci_obs_trace.jsonl + "
                         "BENCH_ci_obs_profile.json)")
    ap.add_argument("--prefill-smoke", action="store_true",
                    help="run only the chunked-prefill smoke (asserts "
                         "chunked-vs-whole-prompt greedy token identity on "
                         "dense AND rwkv, one compiled executable per "
                         "chunk segment length, short-request tokens "
                         "landing before a long-prompt adversary's first, "
                         "and the zero-alloc scratch-pool invariant; the "
                         "CI fast-job invocation)")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="run only the fault-tolerance smoke (seeded "
                         "FaultPlan through the SlotEngine: every request "
                         "terminates inside the closed finish_reason set, "
                         "healthy lanes bit-identical to the fault-free "
                         "run, zero-alloc through quarantine/re-admission, "
                         "ladder downshift + shed visible in the trace; "
                         "the CI fast-job invocation — writes "
                         "BENCH_ci_chaos_trace.jsonl + "
                         "BENCH_ci_chaos_faults.json)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable structured tracing for the whole run and "
                         "write JSONL records (spans/events; see "
                         "ROADMAP §Observability) to PATH")
    ap.add_argument("--fig2", action="store_true",
                    help="run only the fig2 dispatch-count rows + the "
                         "quant/*, rwkv/* and mamba/* rows (the CI "
                         "dispatch-regression guard input — see "
                         "benchmarks/check_dispatch_regression.py)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as JSON (e.g. BENCH_PR4.json) "
                         "for cross-PR perf tracking")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace as trace_lib

        trace_lib.configure(path=args.trace)

    print("name,us_per_call,derived")
    if args.serving:
        bench_serving()
    elif args.train:
        bench_train_step()
    elif args.stream_smoke:
        bench_stream_smoke()
    elif args.quant_smoke:
        bench_quant_smoke()
    elif args.rwkv_smoke:
        bench_rwkv_smoke()
    elif args.mamba_smoke:
        bench_mamba_smoke()
    elif args.obs_smoke:
        bench_obs_smoke()
    elif args.prefill_smoke:
        bench_prefill_smoke()
    elif args.chaos_smoke:
        bench_chaos_smoke()
    elif args.fig2:
        bench_fig2_dispatch_counts()
        bench_quant_rows()
        bench_rwkv_rows()
        bench_mamba_rows()
    else:
        bench_fig2_dispatch_counts()
        bench_quant_rows()
        bench_rwkv_rows()
        bench_chunk_sweep()
        bench_stream_smoke()
        bench_quant_smoke()
        bench_rwkv_smoke()
        bench_fig3_factorization()
        bench_fig4_speedup()
        bench_fig5_complexity()
        bench_fig6_multithread()
        bench_train_step()
        bench_fig7_load()
        bench_serving()
        bench_kernels()
        bench_wkv_chunks()
        bench_moe_capacity()
    print(f"\n{len(ROWS)} benchmarks complete")
    if args.json:
        write_json(args.json)
    if args.trace:
        from repro.obs import trace as trace_lib

        trace_lib.get_tracer().close()
        print(f"wrote trace to {args.trace}")


if __name__ == "__main__":
    main()
