"""Profiling tool for the dry-run artifact: per-collective attribution.

Lists the top collective instructions (result bytes x loop multiplicity)
with their computation — the 'profile' the §Perf hypothesis loop reads,
since wall-clock profiling is impossible on this CPU-only host.

  PYTHONPATH=src python benchmarks/inspect_collectives.py \
      --arch qwen3-moe-30b-a3b --shape prefill_32k [--multi-pod]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402


def main() -> None:
    from repro import analysis, partitioning
    from repro.launch import dryrun

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    jitted, fargs, (cfg, shape, mesh, rules, meta) = dryrun.build_case(
        args.arch, args.shape, args.multi_pod)
    with mesh, partitioning.use_rules(rules):
        compiled = jitted.lower(*fargs).compile()
        hlo = compiled.as_text()

    comps, entry = analysis.parse_hlo_computations(hlo)
    mult = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    for _ in range(len(comps)):
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for name, lines in comps.items():
            m = mult[name]
            if not m:
                continue
            for line in lines:
                wm = analysis._WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = analysis._trip_count(comps.get(cond, []))
                    new[body] = new.get(body, 0.0) + m * trips
                    new[cond] = new.get(cond, 0.0) + m * (trips + 1)
                    continue
                for cm in analysis._CALL_RE.finditer(line):
                    if cm.group(1) in comps:
                        new[cm.group(1)] = new.get(cm.group(1), 0.0) + m
        if new == mult:
            break
        mult = new

    rows = []
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if not m:
            continue
        for line in lines:
            cm = analysis._COLLECTIVE_RE.search(line)
            if cm:
                kind = cm.group(1)
                b = analysis._result_bytes(line, kind)
                rows.append((b * m, m, b, kind, name, line))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"\n{args.arch} x {args.shape}: {len(rows)} collective "
          f"instructions, {total / 1e9:.1f} GB/device total "
          f"(~{total / 50e9:.2f}s serial ICI)\n")
    for scaled, m, raw, kind, comp, line in rows[: args.top]:
        print(f"{scaled / 1e9:9.2f}GB x{m:5.0f} {raw / 1e6:9.1f}MB "
              f"{kind:19s} {comp[:30]:30s} {line[:100]}")


if __name__ == "__main__":
    main()
