"""Dispatch-count regression guard for CI.

MobiRNN's whole thesis is that dispatch count is the enemy on constrained
accelerators, so it is the one benchmark quantity that must NEVER regress
silently.  This checker diffs the ``dispatch``/``train_dispatch`` rows of a
fresh ``benchmarks/run.py --json`` output against a committed baseline
(e.g. BENCH_PR8.json) and exits non-zero on ANY increase — a fused plan
quietly falling back to the per-cell kernel or the oracle VJP shows up here
as a count jump (1 -> T*L, 2 -> T*L), long before wall-clock noise would.
The rwkv/* and mamba/* rows extend the guard past the LSTM family:
pallas_call counts (1 fwd / 2 train at any T) AND grid-step totals
(BH*ceil(T/C) resp. ceil(B/bm)*ceil(T/C) — ``count_pallas_grid_steps`` —
so a silently shrunken chunk or an oracle-replay backward both trip it).

Usage:
    python benchmarks/check_dispatch_regression.py NEW.json BASELINE.json

Rows are matched by name; only rows whose name contains ``dispatch`` are
compared (their ``us_per_call`` field IS the pallas_call / grid-step count
— see benchmarks/run.py fig2/quant/rwkv/mamba rows).  Rows present only in NEW (new
coverage, e.g. quant_* rows against an older baseline) pass with a note;
baseline dispatch rows MISSING from NEW fail — dropped coverage is how a
regression hides.
"""
from __future__ import annotations

import json
import sys


def load_dispatch_rows(path: str) -> dict[str, float]:
    with open(path) as fh:
        rows = json.load(fh)
    return {r["name"]: float(r["us_per_call"]) for r in rows
            if "dispatch" in r["name"]}


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    new_path, base_path = argv[1], argv[2]
    new = load_dispatch_rows(new_path)
    base = load_dispatch_rows(base_path)
    if not base:
        print(f"FAIL: no dispatch rows in baseline {base_path}")
        return 1
    failures = []
    improved = []
    for name, want in sorted(base.items()):
        if name not in new:
            failures.append(f"{name}: missing from {new_path} "
                            f"(baseline={want:.0f}) — dropped coverage")
            continue
        got = new[name]
        if got > want:
            failures.append(f"{name}: {want:.0f} -> {got:.0f} (REGRESSION)")
        elif got < want:
            improved.append(f"{name}: {want:.0f} -> {got:.0f}")
    extra = sorted(set(new) - set(base))
    print(f"compared {len(base)} dispatch rows "
          f"({new_path} vs {base_path})")
    for line in improved:
        print(f"  improved: {line}")
    for name in extra:
        print(f"  new coverage (no baseline): {name}={new[name]:.0f}")
    if failures:
        for line in failures:
            print(f"  FAIL: {line}")
        return 1
    print("OK: no dispatch-count regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
