"""Example: inspect the production-mesh lowering of one (arch x shape).

Shows the public dry-run API: build the abstract case, lower, compile, and
read the roofline terms — the workflow used for every entry in
EXPERIMENTS.md §Roofline.

  PYTHONPATH=src python examples/dryrun_one.py --arch rwkv6-3b \
      --shape decode_32k
"""
# MUST precede any jax-importing module (device count locks on first use).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402


def main() -> None:
    from repro.launch import dryrun

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    rec = dryrun.run_case(args.arch, args.shape, args.multi_pod,
                          out_dir="/tmp/dryrun_example")
    roof = rec["roofline"]
    print(f"\n{args.arch} x {args.shape} on "
          f"{'2x16x16' if args.multi_pod else '16x16'} mesh:")
    print(f"  compile: {rec['compile_s']}s; "
          f"HLO text: {rec['hlo_bytes_text'] / 1e6:.1f}MB")
    print(f"  roofline: compute {roof['t_compute_s']:.3e}s | "
          f"memory {roof['t_memory_s']:.3e}s | "
          f"collective {roof['t_collective_s']:.3e}s")
    print(f"  dominant: {roof['dominant']}; useful flops "
          f"{roof['useful_flops_frac']:.2f}")
    print(f"  collectives: { {k: f'{v/1e9:.2f}GB' for k, v in roof['collective_bytes'].items()} }")


if __name__ == "__main__":
    main()
