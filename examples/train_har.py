"""End-to-end driver: train the paper's activity-recognition LSTM to
convergence (a few hundred steps) and reproduce the §4 evaluation protocol
(latency over 100 test cases, per-plan).

Training runs under any of the registered execution plans
(core/lstm.FORWARD_PLANS) via ``--plan`` — ``fused_seq_q8`` trains
quantization-aware (int8 forward, straight-through grads to f32 masters);
with either fused-seq plan the whole
``value_and_grad`` lowers to TWO Pallas dispatches (one trajectory-emitting
forward + one reverse-sweep BPTT kernel), and the latency table sweeps ALL
registered plans so the Fig 4 comparison covers the Pallas plans too.

  PYTHONPATH=src python examples/train_har.py --steps 300 --hidden 32 \
      --layers 2 --plan fused_seq
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm
from repro.data import har
from repro.optim import AdamW, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--plan", default="sequential",
                    choices=sorted(lstm.FORWARD_PLANS),
                    help="execution plan for the TRAINING step "
                         "(core/lstm.FORWARD_PLANS; fused_seq is the "
                         "single-dispatch MobiRNN fast path, forward and "
                         "backward; fused_seq_q8 is its int8-weight QAT "
                         "variant — equivalent within the int8 error band, "
                         "the rest exactly)")
    ap.add_argument("--latency-cases", type=int, default=100,
                    help="cases for the paper §4.1 latency protocol "
                         "(0 skips it — the CI smoke setting)")
    ap.add_argument("--n-train", type=int, default=7352,
                    help="synthetic train windows (UCI HAR protocol size)")
    ap.add_argument("--n-test", type=int, default=2947)
    args = ap.parse_args()

    forward = lstm.FORWARD_PLANS[args.plan]
    cfg = LSTMConfig().with_complexity(args.hidden, args.layers)
    print(f"config: {cfg.name} ({cfg.n_layers}L x {cfg.hidden}H) "
          f"plan={args.plan}")
    train, test = har.make_har(args.n_train, args.n_test)
    print(f"data: {len(train.y)} train / {len(test.y)} test windows "
          f"(UCI HAR protocol)")

    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps),
                weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(lstm.loss_fn)(params, x, y, cfg,
                                                       forward=forward)
        params, state, m = opt.update(grads, state, params)
        return params, state, loss, m["grad_norm"]

    # a batch larger than the train set would make har.batches yield nothing
    it = har.batches(train, min(args.batch, len(train.y)), seed=0)
    t0 = time.time()
    n_eval = min(512, len(test.y))
    for i in range(1, args.steps + 1):
        bx, by = next(it)
        params, state, loss, gn = step(params, state, jnp.asarray(bx),
                                       jnp.asarray(by))
        if i % 50 == 0 or i == 1:
            acc = lstm.accuracy(params, jnp.asarray(test.x[:n_eval]),
                                jnp.asarray(test.y[:n_eval]), cfg,
                                forward=forward)
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"test_acc {float(acc):.1%} "
                  f"({time.time() - t0:.0f}s)")

    acc = lstm.accuracy(params, jnp.asarray(test.x), jnp.asarray(test.y),
                        cfg, forward=forward)
    print(f"\nfinal test accuracy: {float(acc):.2%}")

    # --- paper §4.1 protocol: latency over N random test cases, for EVERY
    # registered execution plan (Fig 4 covers the Pallas plans too) --------
    n_cases = min(args.latency_cases, len(test.y))
    if n_cases <= 0:
        return
    idx = np.random.default_rng(0).choice(len(test.y), n_cases,
                                          replace=False)
    cases = jnp.asarray(test.x[idx])
    print(f"\nlatency for {n_cases} test cases (paper Fig 4 protocol):")
    for name, fwd in lstm.FORWARD_PLANS.items():
        fn = jax.jit(lambda p, x, fwd=fwd: fwd(p, x, cfg))
        fn(params, cases[:1])  # compile
        t0 = time.perf_counter()
        for j in range(n_cases):
            jax.block_until_ready(fn(params, cases[j:j + 1]))
        dt = time.perf_counter() - t0
        print(f"  {name:12s} {dt * 1e3:8.1f} ms total "
              f"({dt * 1e3 / n_cases:.2f} ms/case)")


if __name__ == "__main__":
    main()
