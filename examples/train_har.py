"""End-to-end driver: train the paper's activity-recognition LSTM to
convergence (a few hundred steps) and reproduce the §4 evaluation protocol
(latency over 100 test cases, per-plan).

  PYTHONPATH=src python examples/train_har.py --steps 300 --hidden 32 \
      --layers 2
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mobirnn_lstm import LSTMConfig
from repro.core import lstm
from repro.data import har
from repro.optim import AdamW, warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = LSTMConfig().with_complexity(args.hidden, args.layers)
    print(f"config: {cfg.name} ({cfg.n_layers}L x {cfg.hidden}H)")
    train, test = har.make_har()
    print(f"data: {len(train.y)} train / {len(test.y)} test windows "
          f"(UCI HAR protocol)")

    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps),
                weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(lstm.loss_fn)(params, x, y, cfg)
        params, state, m = opt.update(grads, state, params)
        return params, state, loss, m["grad_norm"]

    it = har.batches(train, args.batch, seed=0)
    t0 = time.time()
    for i in range(1, args.steps + 1):
        bx, by = next(it)
        params, state, loss, gn = step(params, state, jnp.asarray(bx),
                                       jnp.asarray(by))
        if i % 50 == 0 or i == 1:
            acc = lstm.accuracy(params, jnp.asarray(test.x[:512]),
                                jnp.asarray(test.y[:512]), cfg)
            print(f"step {i:4d} loss {float(loss):.4f} "
                  f"test_acc {float(acc):.1%} "
                  f"({time.time() - t0:.0f}s)")

    acc = lstm.accuracy(params, jnp.asarray(test.x), jnp.asarray(test.y),
                        cfg)
    print(f"\nfinal test accuracy: {float(acc):.2%}")

    # --- paper §4.1 protocol: latency over 100 random test cases ----------
    idx = np.random.default_rng(0).choice(len(test.y), 100, replace=False)
    cases = jnp.asarray(test.x[idx])
    plans = {
        "sequential(fine)": jax.jit(lambda p, x: lstm.forward_sequential(
            p, x, cfg)),
        "wavefront(MobiRNN)": jax.jit(lambda p, x: lstm.forward_wavefront(
            p, x, cfg)),
    }
    print("\nlatency for 100 test cases (paper Fig 4 protocol):")
    for name, fn in plans.items():
        fn(params, cases[:1])  # compile
        t0 = time.perf_counter()
        for j in range(100):
            jax.block_until_ready(fn(params, cases[j:j + 1]))
        dt = time.perf_counter() - t0
        print(f"  {name:20s} {dt * 1e3:8.1f} ms total "
              f"({dt * 10:.2f} ms/case)")


if __name__ == "__main__":
    main()
