"""Serving example: a reduced qwen2 under the MobiRNN runtime policies —
preallocated cache pools, load-aware plan dispatch (paper Fig 7, but for
LLM decode) — comparing the two engines:

  * wave (Engine):       lockstep batches, padded to the slowest request;
  * slot (SlotEngine):   slot-resident continuous batching — per-lane
                         admission/retirement over one preallocated cache,
                         tokens streamed per tick.

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --trace serve_trace.jsonl
  PYTHONPATH=src python examples/serve_lm.py --chaos

With ``--trace`` the whole run is recorded as structured JSONL (per-tick
serve/tick spans with the chosen plan, serve/admit events with per-request
TTFT, nested sched/choose decisions, and a final serve/metrics summary —
see ROADMAP §Observability for the schema).

With ``--chaos`` the slot engine runs under a seeded FaultPlan
(ROADMAP §Robustness): the client submits through the bounded queue with
EXPONENTIAL BACKOFF on QueueFull (the intended reaction to backpressure),
the engine quarantines poisoned lanes / retries failed prefills / steps
its degradation ladder, and the run ends with the per-reason retirement
breakdown over the closed finish_reason set.
"""
import argparse
import collections
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.scheduler import SyntheticLoadSensor
from repro.models import registry
from repro.partitioning import split
from repro.serving import (Engine, EngineConfig, FaultPlan, QueueFull,
                           Request, SlotEngine)


def make_requests(cfg, rng):
    # ragged on purpose: mixed prompt lengths, 8x max_new spread — the
    # workload where continuous batching beats waves
    lens = [8, 12, 6, 16, 8, 12, 6, 16, 8, 12, 6, 16]
    news = [2, 16, 4, 8, 16, 2, 8, 4, 16, 2, 4, 8]
    return [Request(i, rng.integers(0, cfg.vocab, (l,)).astype(np.int32),
                    max_new_tokens=n)
            for i, (l, n) in enumerate(zip(lens, news))]


def run_chaos(cfg, model, params) -> None:
    from repro import steps as steps_lib

    rng = np.random.default_rng(1)
    reqs = make_requests(cfg, rng)
    plan = FaultPlan.seeded(
        0, n_slots=2, ticks=16, uids=tuple(r.uid for r in reqs),
        n_poison=2, n_prefill=1, n_slow_burst=1, slow_extra_s=1e6,
        n_flood=1, flood_n=2)
    kinds = collections.Counter(type(f).__name__ for f in plan.faults)
    print(f"chaos: seed={plan.seed} schedule="
          + " ".join(f"{k}x{n}" for k, n in sorted(kinds.items())))

    # small queue ON PURPOSE: the client below must hit QueueFull and
    # back off, which is the intended reaction to engine backpressure
    engine = SlotEngine(
        model, params,
        config=EngineConfig(
            n_slots=2, max_seq=64, queue_capacity=3,
            faults=plan, retry_budget=1, retry_backoff_s=0.005,
            tick_slo_s=50.0, slo_breach_ticks=3, slo_recover_ticks=8,
            ladder=["decode/base"]),
        extra_plans={"decode/fallback":
                     lambda p, c, b: steps_lib.decode_step(cfg, p, c, b)})

    pending = collections.deque(reqs)
    backoff_s, backoffs = 0.005, 0

    def pump() -> None:
        # exponential backoff on QueueFull: sleep, double the delay, and
        # yield control back to the stream so the engine can drain lanes;
        # any accepted submit resets the delay to its floor
        nonlocal backoff_s, backoffs
        while pending:
            try:
                engine.submit(pending[0])
            except QueueFull:
                backoffs += 1
                time.sleep(backoff_s)
                backoff_s = min(backoff_s * 2, 0.08)
                return
            pending.popleft()            # queued (or retired dead-on-arrival)
            backoff_s = 0.005

    n_tokens = 0
    while pending:
        pump()
        for ev in engine.stream():
            n_tokens += ev.token is not None
            if pending:
                pump()

    results = engine.take_finished()
    breakdown = collections.Counter(r.finish_reason for r in results.values())
    print(f"chaos: {len(results)} retired ({n_tokens} tokens streamed), "
          "breakdown: "
          + " ".join(f"{k}={n}" for k, n in sorted(breakdown.items())))
    m = engine.metrics
    print(f"chaos: client QueueFull backoffs={backoffs}; engine "
          f"quarantined={m.counter('serving/quarantined').value} "
          f"retries={m.counter('serving/retries').value} "
          f"shed={m.counter('serving/shed').value} "
          f"deadline_miss={m.counter('serving/deadline_miss').value}")
    print(f"chaos: ladder level={engine.scheduler.level} "
          f"(0 = recovered); resident pool: {engine.pool.stats}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a structured JSONL trace of the run")
    ap.add_argument("--chaos", action="store_true",
                    help="run the slot engine under a seeded FaultPlan "
                         "with client-side backoff on QueueFull")
    args = ap.parse_args()
    if args.trace:
        from repro.obs import trace as trace_lib

        trace_lib.configure(path=args.trace)

    cfg = get_arch("qwen2-0.5b").reduced()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    print(f"serving {cfg.name}: vocab={cfg.vocab} layers={cfg.n_layers}")

    if args.chaos:
        run_chaos(cfg, model, params)
        if args.trace:
            from repro.obs import trace as trace_lib

            trace_lib.get_tracer().close()
            print(f"wrote trace to {args.trace}")
        return

    rng = np.random.default_rng(0)
    reqs = make_requests(cfg, rng)
    n_tok = sum(r.max_new_tokens for r in reqs)

    sensor = SyntheticLoadSensor(0.0)
    wave = Engine(model, params, sensor=sensor, config=EngineConfig(
        n_slots=4, max_seq=64, pool_capacity=2))
    slot = SlotEngine(model, params, sensor=sensor, config=EngineConfig(
        n_slots=4, max_seq=64, queue_capacity=8))

    wave.serve(reqs)                   # compile both engines once so the
    slot.serve(reqs)                   # printed rows are steady-state

    for load in (0.0, 0.85):
        sensor.value = load
        for name, engine in (("wave", wave), ("slot", slot)):
            t0 = time.time()
            results = engine.serve(reqs)
            wall = time.time() - t0
            plans = {p for r in results for p in r.plan_decisions}
            print(f"load={load:.0%} {name}: {len(results)} requests, "
                  f"{n_tok} tokens, {n_tok / wall:.1f} tok/s, "
                  f"plans used: {plans}")

    # streaming: tokens surface per tick, not when the whole batch drains;
    # TTFT is measured by the engine itself (admit -> first token on host)
    # and surfaced both per request on Result.ttft_s and as a p50/p99
    # histogram in the engine's always-on serving metrics
    results = slot.serve(reqs)
    for r in sorted(results, key=lambda r: r.ttft_s)[:3]:
        print(f"  uid={r.uid}: ttft={r.ttft_s * 1e3:.1f}ms "
              f"decode={r.decode_s * 1e3:.1f}ms "
              f"tokens={r.tokens.shape[-1]}")
    ttft = slot.metrics.histogram("serving/ttft_s").summary()
    tbt = slot.metrics.histogram("serving/tbt_s").summary()
    print(f"slot streaming: ttft p50={ttft['p50'] * 1e3:.1f}ms "
          f"p99={ttft['p99'] * 1e3:.1f}ms; "
          f"tbt p50={tbt['p50'] * 1e3:.2f}ms p99={tbt['p99'] * 1e3:.2f}ms")
    print("resident pool:", slot.pool.stats)
    if args.trace:
        from repro.obs import trace as trace_lib

        trace_lib.get_tracer().close()
        print(f"wrote trace to {args.trace}")


if __name__ == "__main__":
    main()
