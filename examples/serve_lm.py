"""Serving example: batched requests against a reduced qwen2 with the
MobiRNN runtime policies — preallocated cache pools, coarse request waves,
and load-aware plan dispatch under varying injected load (paper Fig 7, but
for LLM decode).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.scheduler import SyntheticLoadSensor
from repro.models import registry
from repro.partitioning import split
from repro.serving import Engine, Request


def main() -> None:
    cfg = get_arch("qwen2-0.5b").reduced()
    model = registry.build(cfg)
    params, _ = split(model.init(jax.random.PRNGKey(0)))
    print(f"serving {cfg.name}: vocab={cfg.vocab} layers={cfg.n_layers}")

    sensor = SyntheticLoadSensor(0.0)
    engine = Engine(model, params, batch_size=4, max_seq=64,
                    pool_capacity=2, sensor=sensor)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, (12,)).astype(np.int32),
                    max_new_tokens=8) for i in range(12)]

    for load in (0.0, 0.85):
        sensor.value = load
        t0 = time.time()
        results = engine.serve(reqs)
        wall = time.time() - t0
        n_tok = sum(r.tokens.shape[-1] for r in results)
        plans = {p for r in results for p in r.plan_decisions}
        print(f"load={load:.0%}: {len(results)} requests, {n_tok} tokens, "
              f"{n_tok / wall:.1f} tok/s, plans used: {plans}")
    print("state pool:", engine.pool.stats)


if __name__ == "__main__":
    main()
