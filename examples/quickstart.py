"""Quickstart: the paper's model end to end in ~60 lines.

Builds MobiRNN's 2-layer x 32-hidden stacked LSTM, runs it under the
registered execution plans (sequential, wavefront, per-cell fused Pallas
kernel, the sequence-resident Pallas kernel — one dispatch for the whole
sequence — and its int8-weight variant), verifies they agree (the q8 plan
within its int8 error band), trains it briefly on the synthetic HAR data,
and shows the load-aware scheduler choosing a backend — the whole paper in
miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MOBIRNN_LSTM
from repro.core import lstm, wavefront
from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.data import har
from repro.optim import AdamW


def main() -> None:
    cfg = MOBIRNN_LSTM
    print(f"model: {cfg.n_layers} layers x {cfg.hidden} hidden "
          f"(paper default)")
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.seq_len,
                                                  cfg.input_dim))

    # --- five execution plans, one result ---------------------------------
    seq = lstm.forward_sequential(params, x, cfg)
    wave = lstm.forward_wavefront(params, x, cfg)
    fused = lstm.forward_fused_kernel(params, x[:, :16], cfg)
    fused_seq = lstm.forward_fused_seq(params, x, cfg)
    fused_q8 = lstm.forward_fused_seq_q8(params, x, cfg)
    print("wavefront == sequential:",
          bool(jnp.allclose(seq, wave, atol=1e-4)))
    print("fused_seq == sequential:",
          bool(jnp.allclose(seq, fused_seq, atol=1e-4)))
    print("fused_seq_q8 within int8 band:",
          bool(jnp.allclose(seq, fused_q8, atol=5e-2)))
    print(f"wavefront width: {wavefront.wavefront_width(cfg.n_layers, 4)} "
          f"-> {wavefront.live_buffers(cfg.n_layers, 4)} preallocated "
          f"buffers (paper Fig 1: 6 instead of 24)")
    from repro.analysis import count_kernel_dispatches
    n = count_kernel_dispatches(jax.make_jaxpr(
        lambda p, x: lstm.forward_fused_seq(p, x, cfg))(params, x))
    print(f"fused_seq kernel dispatches for T={cfg.seq_len}: {n} "
          f"(per-cell plan: {cfg.seq_len * cfg.n_layers})")
    del fused

    # --- brief training on HAR -------------------------------------------
    train, test = har.make_har(n_train=512, n_test=256)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(lstm.loss_fn)(params, x, y, cfg)
        return *opt.update(grads, state, params)[:2], loss

    it = har.batches(train, 64)
    for i in range(40):
        bx, by = next(it)
        params, state, loss = step(params, state, jnp.asarray(bx),
                                   jnp.asarray(by))
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(loss):.3f}")
    acc = lstm.accuracy(params, jnp.asarray(test.x), jnp.asarray(test.y),
                        cfg)
    print(f"test accuracy: {float(acc):.1%} (chance = 16.7%)")

    # --- load-aware dispatch (paper Fig 7) --------------------------------
    sensor = SyntheticLoadSensor(0.0)
    sched = Scheduler(sensor)
    sched.register(Plan("accel/wavefront",
                        jax.jit(lambda p, x: lstm.forward_wavefront(
                            p, x, cfg)), shared=True))
    sched.register(Plan("accel/fused_seq",
                        jax.jit(lambda p, x: lstm.forward_fused_seq(
                            p, x, cfg)), shared=True))
    sched.register(Plan("cpu/sequential",
                        jax.jit(lambda p, x: lstm.forward_sequential(
                            p, x, cfg)), shared=False))
    sched.calibrate(params, x)
    for load in (0.1, 0.9):
        sensor.value = load
        _, decision = sched.run(params, x)
        print(f"load={load:.0%}: dispatched to {decision.plan}")


if __name__ == "__main__":
    main()
