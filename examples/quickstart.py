"""Quickstart: the paper's model end to end in ~60 lines.

Builds MobiRNN's 2-layer x 32-hidden stacked LSTM, runs it under all three
execution plans (sequential, wavefront, fused Pallas kernel), verifies they
agree, trains it briefly on the synthetic HAR data, and shows the load-aware
scheduler choosing a backend — the whole paper in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MOBIRNN_LSTM
from repro.core import lstm, wavefront
from repro.core.scheduler import Plan, Scheduler, SyntheticLoadSensor
from repro.data import har
from repro.optim import AdamW


def main() -> None:
    cfg = MOBIRNN_LSTM
    print(f"model: {cfg.n_layers} layers x {cfg.hidden} hidden "
          f"(paper default)")
    params = lstm.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.seq_len,
                                                  cfg.input_dim))

    # --- three execution plans, one result --------------------------------
    seq = lstm.forward_sequential(params, x, cfg)
    wave = lstm.forward_wavefront(params, x, cfg)
    fused = lstm.forward_fused_kernel(params, x[:, :16], cfg)
    print("wavefront == sequential:",
          bool(jnp.allclose(seq, wave, atol=1e-4)))
    print(f"wavefront width: {wavefront.wavefront_width(cfg.n_layers, 4)} "
          f"-> {wavefront.live_buffers(cfg.n_layers, 4)} preallocated "
          f"buffers (paper Fig 1: 6 instead of 24)")
    del fused

    # --- brief training on HAR -------------------------------------------
    train, test = har.make_har(n_train=512, n_test=256)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        loss, grads = jax.value_and_grad(lstm.loss_fn)(params, x, y, cfg)
        return *opt.update(grads, state, params)[:2], loss

    it = har.batches(train, 64)
    for i in range(40):
        bx, by = next(it)
        params, state, loss = step(params, state, jnp.asarray(bx),
                                   jnp.asarray(by))
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(loss):.3f}")
    acc = lstm.accuracy(params, jnp.asarray(test.x), jnp.asarray(test.y),
                        cfg)
    print(f"test accuracy: {float(acc):.1%} (chance = 16.7%)")

    # --- load-aware dispatch (paper Fig 7) --------------------------------
    sensor = SyntheticLoadSensor(0.0)
    sched = Scheduler(sensor)
    sched.register(Plan("accel/wavefront",
                        jax.jit(lambda p, x: lstm.forward_wavefront(
                            p, x, cfg)), shared=True))
    sched.register(Plan("cpu/sequential",
                        jax.jit(lambda p, x: lstm.forward_sequential(
                            p, x, cfg)), shared=False))
    sched.calibrate(params, x)
    for load in (0.1, 0.9):
        sensor.value = load
        _, decision = sched.run(params, x)
        print(f"load={load:.0%}: dispatched to {decision.plan}")


if __name__ == "__main__":
    main()
